//! Pairwise TP/FP/FN/TN characterization of raw ReID results (§4.2.1) —
//! the machinery behind Table 2, also reused by the filter evaluation.

use crate::reid::records::ReidStream;

/// Counts of the four §4.2.1 label types for one (source, dest) pair.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PairCounts {
    pub tp: usize,
    pub fp: usize,
    pub fn_: usize,
    pub tn: usize,
}

impl PairCounts {
    pub fn total(&self) -> usize {
        self.tp + self.fp + self.fn_ + self.tn
    }
}

/// Label every detection of `src` against `dst`:
///
/// * *positive*  — its raw id also appears in `dst` at the same frame;
/// * *gt-positive* — its true id has a ground-truth appearance in `dst`.
///
/// TP: positive matched to the right vehicle; FP: positive matched to the
/// wrong one (either §4.2.1 FP case); FN: not positive but gt-positive;
/// TN: neither.
pub fn characterize_pair(stream: &ReidStream, src: usize, dst: usize) -> PairCounts {
    let mut counts = PairCounts::default();
    for frame in 0..stream.n_frames {
        // true ids present in dst this frame (ground-truth presence)
        let dst_true: Vec<u32> = stream.at(dst, frame).map(|d| d.true_id).collect();
        for det in stream.at(src, frame) {
            let matched = stream.find_id(dst, frame, det.raw_id);
            let gt_positive = dst_true.contains(&det.true_id);
            match matched {
                Some(m) => {
                    if m.true_id == det.true_id {
                        counts.tp += 1;
                    } else {
                        counts.fp += 1;
                    }
                }
                None => {
                    if gt_positive {
                        counts.fn_ += 1;
                    } else {
                        counts.tn += 1;
                    }
                }
            }
        }
    }
    counts
}

/// The full N×N matrix (diagonal unused), i.e. Table 2.
pub fn characterize_all(stream: &ReidStream) -> Vec<Vec<PairCounts>> {
    let n = stream.n_cameras;
    (0..n)
        .map(|s| {
            (0..n)
                .map(|d| {
                    if s == d {
                        PairCounts::default()
                    } else {
                        characterize_pair(stream, s, d)
                    }
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reid::records::RawDetection;
    use crate::util::geometry::Rect;

    fn det(cam: usize, frame: usize, raw_id: u32, true_id: u32) -> RawDetection {
        RawDetection { cam, frame, bbox: Rect::new(0.0, 0.0, 10.0, 10.0), raw_id, true_id }
    }

    #[test]
    fn tp_when_ids_agree() {
        let s = ReidStream::new(2, 1, vec![det(0, 0, 5, 5), det(1, 0, 5, 5)]);
        let c = characterize_pair(&s, 0, 1);
        assert_eq!(c, PairCounts { tp: 1, fp: 0, fn_: 0, tn: 0 });
    }

    #[test]
    fn fp_when_matched_to_wrong_vehicle() {
        // src vehicle 5 matched to raw id 5 in dst, but dst raw 5 is truly vehicle 9
        let s = ReidStream::new(2, 1, vec![det(0, 0, 5, 5), det(1, 0, 5, 9)]);
        let c = characterize_pair(&s, 0, 1);
        assert_eq!(c.fp, 1);
    }

    #[test]
    fn fn_when_identity_broken() {
        // same true vehicle in both cams, different raw ids
        let s = ReidStream::new(2, 1, vec![det(0, 0, 5, 5), det(1, 0, 77, 5)]);
        let c = characterize_pair(&s, 0, 1);
        assert_eq!(c.fn_, 1);
        // reverse direction is symmetric here
        let c2 = characterize_pair(&s, 1, 0);
        assert_eq!(c2.fn_, 1);
    }

    #[test]
    fn tn_when_truly_absent() {
        let s = ReidStream::new(2, 1, vec![det(0, 0, 5, 5), det(1, 0, 6, 6)]);
        let c = characterize_pair(&s, 0, 1);
        assert_eq!(c, PairCounts { tp: 0, fp: 0, fn_: 1 * 0, tn: 1 });
    }

    #[test]
    fn matrix_shape() {
        let s = ReidStream::new(3, 1, vec![det(0, 0, 1, 1), det(1, 0, 1, 1), det(2, 0, 2, 2)]);
        let m = characterize_all(&s);
        assert_eq!(m.len(), 3);
        assert_eq!(m[0][1].tp, 1);
        assert_eq!(m[0][2].tn, 1);
        assert_eq!(m[0][0].total(), 0);
    }
}
