//! Detector post-processing: objectness grid → bounding boxes.
//!
//! Cells above the threshold are grouped by 4-connectivity; each component
//! becomes one detection whose bbox is the union of its cells (the paper's
//! YOLO head regresses boxes — our analytic head localizes at cell
//! resolution, which is all the unique-vehicle query needs).

use crate::util::geometry::Rect;

/// One decoded detection.
#[derive(Debug, Clone)]
pub struct Detection {
    pub bbox: Rect,
    /// Peak objectness of the component.
    pub score: f64,
}

/// Reusable traversal buffers for [`decode_objectness_into`] — one per
/// thread lets the server's steady-state decode run allocation-free;
/// the buffers grow to the grid size on first use.
#[derive(Debug, Default)]
pub struct DecodeScratch {
    active: Vec<bool>,
    visited: Vec<bool>,
    stack: Vec<usize>,
}

impl DecodeScratch {
    pub fn new() -> DecodeScratch {
        DecodeScratch::default()
    }
}

/// Decode an objectness grid (`cells_h × cells_w`, row-major, cell size
/// `cell_px`) into detections.
///
/// Allocating convenience wrapper over [`decode_objectness_into`].
pub fn decode_objectness(
    grid: &[f32],
    cells_h: usize,
    cells_w: usize,
    cell_px: usize,
    threshold: f64,
) -> Vec<Detection> {
    let mut scratch = DecodeScratch::default();
    let mut out = Vec::new();
    decode_objectness_into(grid, cells_h, cells_w, cell_px, threshold, &mut scratch, &mut out);
    out
}

/// [`decode_objectness`] writing into `out` (cleared and overwritten)
/// with the component traversal's buffers in `scratch`.
pub fn decode_objectness_into(
    grid: &[f32],
    cells_h: usize,
    cells_w: usize,
    cell_px: usize,
    threshold: f64,
    scratch: &mut DecodeScratch,
    out: &mut Vec<Detection>,
) {
    assert_eq!(grid.len(), cells_h * cells_w);
    out.clear();
    let active = &mut scratch.active;
    active.clear();
    active.extend(grid.iter().map(|&v| v as f64 > threshold));
    let visited = &mut scratch.visited;
    visited.clear();
    visited.resize(grid.len(), false);
    let stack = &mut scratch.stack;
    for start in 0..grid.len() {
        if !active[start] || visited[start] {
            continue;
        }
        // BFS over the component
        stack.clear();
        stack.push(start);
        visited[start] = true;
        let (mut min_x, mut max_x) = (cells_w, 0usize);
        let (mut min_y, mut max_y) = (cells_h, 0usize);
        let mut peak = 0.0f64;
        while let Some(i) = stack.pop() {
            let (y, x) = (i / cells_w, i % cells_w);
            min_x = min_x.min(x);
            max_x = max_x.max(x);
            min_y = min_y.min(y);
            max_y = max_y.max(y);
            peak = peak.max(grid[i] as f64);
            let mut push = |j: usize| {
                if active[j] && !visited[j] {
                    visited[j] = true;
                    stack.push(j);
                }
            };
            if x > 0 {
                push(i - 1);
            }
            if x + 1 < cells_w {
                push(i + 1);
            }
            if y > 0 {
                push(i - cells_w);
            }
            if y + 1 < cells_h {
                push(i + cells_w);
            }
        }
        out.push(Detection {
            bbox: Rect::new(
                (min_x * cell_px) as f64,
                (min_y * cell_px) as f64,
                ((max_x - min_x + 1) * cell_px) as f64,
                ((max_y - min_y + 1) * cell_px) as f64,
            ),
            score: peak,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid_with(cells: &[(usize, usize, f32)], h: usize, w: usize) -> Vec<f32> {
        let mut g = vec![0.0f32; h * w];
        for &(y, x, v) in cells {
            g[y * w + x] = v;
        }
        g
    }

    #[test]
    fn empty_grid_no_detections() {
        let g = vec![0.0f32; 12 * 20];
        assert!(decode_objectness(&g, 12, 20, 16, 0.25).is_empty());
    }

    #[test]
    fn single_component_bbox() {
        let g = grid_with(&[(2, 3, 0.9), (2, 4, 0.8), (3, 3, 0.7)], 12, 20);
        let dets = decode_objectness(&g, 12, 20, 16, 0.25);
        assert_eq!(dets.len(), 1);
        let d = &dets[0];
        assert_eq!(d.bbox, Rect::new(48.0, 32.0, 32.0, 32.0));
        assert!((d.score - 0.9).abs() < 1e-6);
    }

    #[test]
    fn two_separate_components() {
        let g = grid_with(&[(0, 0, 0.5), (11, 19, 0.6)], 12, 20);
        let dets = decode_objectness(&g, 12, 20, 16, 0.25);
        assert_eq!(dets.len(), 2);
    }

    #[test]
    fn diagonal_cells_are_distinct_components() {
        let g = grid_with(&[(1, 1, 0.5), (2, 2, 0.5)], 12, 20);
        let dets = decode_objectness(&g, 12, 20, 16, 0.25);
        assert_eq!(dets.len(), 2, "4-connectivity must not merge diagonals");
    }

    #[test]
    fn into_variant_matches_allocating_api_across_reuses() {
        let a = grid_with(&[(2, 3, 0.9), (2, 4, 0.8), (3, 3, 0.7)], 12, 20);
        let b = grid_with(&[(0, 0, 0.5), (11, 19, 0.6)], 12, 20);
        let mut scratch = DecodeScratch::new();
        let mut dets = Vec::new();
        // alternating grids through one scratch: stale active/visited
        // state must never leak between decodes
        for _ in 0..2 {
            decode_objectness_into(&a, 12, 20, 16, 0.25, &mut scratch, &mut dets);
            assert_eq!(dets.len(), 1);
            assert_eq!(dets[0].bbox, Rect::new(48.0, 32.0, 32.0, 32.0));
            decode_objectness_into(&b, 12, 20, 16, 0.25, &mut scratch, &mut dets);
            assert_eq!(dets.len(), 2);
        }
    }

    #[test]
    fn threshold_filters_weak_cells() {
        let g = grid_with(&[(5, 5, 0.2), (6, 6, 0.3)], 12, 20);
        assert_eq!(decode_objectness(&g, 12, 20, 16, 0.25).len(), 1);
        assert_eq!(decode_objectness(&g, 12, 20, 16, 0.1).len(), 2);
        assert_eq!(decode_objectness(&g, 12, 20, 16, 0.5).len(), 0);
    }
}
