//! PJRT runtime: loads the AOT-compiled detector HLO artifacts (produced
//! once by `python/compile/aot.py`) and executes them on the request path.
//! Python never runs here — the rust binary is self-contained after
//! `make artifacts` (see /opt/xla-example/load_hlo for the pattern).

#[cfg(feature = "pjrt")]
pub mod client;
pub mod contract;
pub mod native;
pub mod postproc;

#[cfg(feature = "pjrt")]
pub use client::Runtime;
pub use contract::Contract;
pub use postproc::{decode_objectness, Detection};
