//! The L2 ↔ L3 geometry contract.
//!
//! `python/compile/model.py` bakes these constants into the HLO artifacts;
//! `aot.py` exports them to `artifacts/meta.json`; this module carries the
//! rust copy and verifies the two agree at runtime load, so a drifted
//! artifact set fails loudly instead of mis-decoding tensors.

use anyhow::{bail, Context, Result};

use crate::util::json;

/// Detector geometry (see model.py's module docstring).
#[derive(Debug, Clone, PartialEq)]
pub struct Contract {
    pub frame_h: usize,
    pub frame_w: usize,
    pub channels: usize,
    pub block: usize,
    pub cell: usize,
    pub halo: usize,
    pub grid_bh: usize,
    pub grid_bw: usize,
    pub n_blocks: usize,
    pub cells_h: usize,
    pub cells_w: usize,
    pub cells_per_block: usize,
    pub roi_capacities: Vec<usize>,
    pub objectness_threshold: f64,
}

impl Contract {
    /// The constants this crate was built against.
    pub fn expected() -> Contract {
        Contract {
            frame_h: 192,
            frame_w: 320,
            channels: 3,
            block: 32,
            cell: 16,
            halo: 3,
            grid_bh: 6,
            grid_bw: 10,
            n_blocks: 60,
            cells_h: 12,
            cells_w: 20,
            cells_per_block: 2,
            roi_capacities: vec![8, 16, 32, 60],
            objectness_threshold: 0.25,
        }
    }

    /// Parse `meta.json` as emitted by aot.py.
    pub fn from_meta_json(text: &str) -> Result<Contract> {
        let v = json::parse(text).map_err(|e| anyhow::anyhow!("meta.json: {e}"))?;
        let get = |k: &str| -> Result<usize> {
            v.get(k).and_then(|j| j.as_usize()).with_context(|| format!("meta.json missing {k}"))
        };
        Ok(Contract {
            frame_h: get("frame_h")?,
            frame_w: get("frame_w")?,
            channels: get("channels")?,
            block: get("block")?,
            cell: get("cell")?,
            halo: get("halo")?,
            grid_bh: get("grid_bh")?,
            grid_bw: get("grid_bw")?,
            n_blocks: get("n_blocks")?,
            cells_h: get("cells_h")?,
            cells_w: get("cells_w")?,
            cells_per_block: get("cells_per_block")?,
            roi_capacities: v
                .get("roi_capacities")
                .and_then(|j| j.as_arr())
                .context("meta.json missing roi_capacities")?
                .iter()
                .map(|j| j.as_usize().context("bad capacity"))
                .collect::<Result<Vec<_>>>()?,
            objectness_threshold: v
                .get("objectness_threshold")
                .and_then(|j| j.as_f64())
                .context("meta.json missing objectness_threshold")?,
        })
    }

    /// Load and verify against [`Contract::expected`].
    pub fn load_verified(artifacts_dir: &str) -> Result<Contract> {
        let path = format!("{artifacts_dir}/meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path} — run `make artifacts` first"))?;
        let got = Contract::from_meta_json(&text)?;
        let want = Contract::expected();
        if got != want {
            bail!(
                "artifact contract mismatch:\n  artifacts: {got:?}\n  crate:     {want:?}\n\
                 regenerate with `make artifacts`"
            );
        }
        Ok(got)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expected_is_self_consistent() {
        let c = Contract::expected();
        assert_eq!(c.frame_h % c.block, 0);
        assert_eq!(c.frame_w % c.block, 0);
        assert_eq!(c.block % c.cell, 0);
        assert_eq!(c.n_blocks, c.grid_bh * c.grid_bw);
        assert_eq!(c.cells_h, c.frame_h / c.cell);
        assert_eq!(c.cells_w, c.frame_w / c.cell);
        assert_eq!(*c.roi_capacities.last().unwrap(), c.n_blocks);
        // matches the simulator's frame geometry
        assert_eq!(c.frame_w as u32, crate::sim::FRAME_W);
        assert_eq!(c.frame_h as u32, crate::sim::FRAME_H);
    }

    #[test]
    fn parses_meta_json() {
        let text = r#"{
            "frame_h": 192, "frame_w": 320, "channels": 3, "block": 32,
            "cell": 16, "halo": 3, "grid_bh": 6, "grid_bw": 10,
            "n_blocks": 60, "cells_h": 12, "cells_w": 20,
            "cells_per_block": 2, "roi_capacities": [8, 16, 32, 60],
            "objectness_threshold": 0.25
        }"#;
        let c = Contract::from_meta_json(text).unwrap();
        assert_eq!(c, Contract::expected());
    }

    #[test]
    fn rejects_drifted_meta() {
        let text = r#"{"frame_h": 128}"#;
        assert!(Contract::from_meta_json(text).is_err());
    }
}
