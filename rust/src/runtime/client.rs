//! The PJRT client wrapper: one compiled executable per detector variant
//! (dense full-frame + one RoI variant per padded block capacity K).
//!
//! The RoI path is the paper's SBNet pipeline (§4.4): the rust side
//! supplies the frame and the active block ids (from the offline RoI
//! masks), the L1 Pallas kernel inside the HLO does gather → conv stack →
//! per-block cells, and [`Runtime::infer_roi`] scatters the cells back
//! into the full objectness grid.  Like the paper, the runtime falls back
//! to the dense model when the RoI covers (nearly) the whole frame — the
//! gather/scatter overhead only pays off on sparse masks.

use anyhow::{bail, Context, Result};

use crate::runtime::contract::Contract;

/// Loaded detector executables.
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    full: xla::PjRtLoadedExecutable,
    /// (capacity K, executable), ascending by K.
    roi: Vec<(usize, xla::PjRtLoadedExecutable)>,
    pub contract: Contract,
}

// NOTE: `RuntimeInfer` requires `Runtime: Sync` (the pipeline's `Infer`
// trait is `Sync`).  The in-tree stub's types are trivially Sync; when
// swapping in real PJRT bindings whose handles are `!Sync`, wrap them
// (e.g. a mutex around execution) rather than asserting `unsafe impl
// Sync` here — the compile error at `RuntimeInfer` is the safety net.

impl Runtime {
    /// Load and compile every artifact in `artifacts_dir`.
    pub fn load(artifacts_dir: &str) -> Result<Runtime> {
        let contract = Contract::load_verified(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let load = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = format!("{artifacts_dir}/{name}");
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing {path}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).with_context(|| format!("compiling {path}"))
        };
        let full = load("detector_full.hlo.txt")?;
        let mut roi = Vec::new();
        for &k in &contract.roi_capacities {
            roi.push((k, load(&format!("detector_roi_k{k}.hlo.txt"))?));
        }
        Ok(Runtime { client, full, roi, contract })
    }

    /// Dense full-frame inference: `frame` is HWC f32 in [0,1], length
    /// `frame_h * frame_w * 3`.  Returns the (cells_h × cells_w)
    /// objectness grid, row-major.
    pub fn infer_full(&self, frame: &[f32]) -> Result<Vec<f32>> {
        let c = &self.contract;
        let expect = c.frame_h * c.frame_w * c.channels;
        if frame.len() != expect {
            bail!("frame length {} != {expect}", frame.len());
        }
        let x = xla::Literal::vec1(frame).reshape(&[
            c.frame_h as i64,
            c.frame_w as i64,
            c.channels as i64,
        ])?;
        let result = self.full.execute::<xla::Literal>(&[x])?[0][0].to_literal_sync()?;
        let grid = result.to_tuple1()?.to_vec::<f32>()?;
        if grid.len() != c.cells_h * c.cells_w {
            bail!("unexpected objectness size {}", grid.len());
        }
        Ok(grid)
    }

    /// Pick the smallest compiled capacity ≥ `n`; None if n exceeds all.
    pub fn capacity_for(&self, n: usize) -> Option<usize> {
        self.roi.iter().map(|(k, _)| *k).find(|&k| k >= n)
    }

    /// RoI inference via the SBNet block variant.
    ///
    /// `blocks` are active block ids (ascending, each in `0..n_blocks`).
    /// Returns the full objectness grid with inactive blocks at 0, plus
    /// the capacity K actually used.  Falls back to [`Self::infer_full`]
    /// when `blocks` exceeds every compiled capacity (never happens with
    /// the shipped artifacts: max K = all blocks).
    pub fn infer_roi(&self, frame: &[f32], blocks: &[i32]) -> Result<(Vec<f32>, usize)> {
        let c = &self.contract;
        let Some(k) = self.capacity_for(blocks.len()) else {
            return Ok((self.infer_full(frame)?, c.n_blocks));
        };
        let exe = &self.roi.iter().find(|(cap, _)| *cap == k).unwrap().1;
        let x = xla::Literal::vec1(frame).reshape(&[
            c.frame_h as i64,
            c.frame_w as i64,
            c.channels as i64,
        ])?;
        let mut ids = blocks.to_vec();
        debug_assert!(ids.windows(2).all(|w| w[0] < w[1]), "blocks must be ascending");
        debug_assert!(ids.iter().all(|&b| (b as usize) < c.n_blocks));
        ids.resize(k, -1);
        let ids_lit = xla::Literal::vec1(&ids);
        let result = exe.execute::<xla::Literal>(&[x, ids_lit])?[0][0].to_literal_sync()?;
        let cells = result.to_tuple1()?.to_vec::<f32>()?;
        let cpb = c.cells_per_block;
        if cells.len() != k * cpb * cpb {
            bail!("unexpected RoI cell tensor size {}", cells.len());
        }
        // scatter (K, cpb, cpb) -> (cells_h, cells_w)
        let mut grid = vec![0.0f32; c.cells_h * c.cells_w];
        for (slot, &bid) in ids.iter().enumerate() {
            if bid < 0 {
                continue;
            }
            let by = bid as usize / c.grid_bw;
            let bx = bid as usize % c.grid_bw;
            for cy in 0..cpb {
                for cx in 0..cpb {
                    grid[(by * cpb + cy) * c.cells_w + bx * cpb + cx] =
                        cells[slot * cpb * cpb + cy * cpb + cx];
                }
            }
        }
        Ok((grid, k))
    }
}

// Integration tests that exercise the actual artifacts live in
// rust/tests/runtime_hlo.rs (they need `make artifacts` to have run).
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_artifacts_fail_loudly() {
        let msg = match Runtime::load("/nonexistent-artifacts") {
            Ok(_) => panic!("loading from a nonexistent dir succeeded"),
            Err(e) => format!("{e:#}"),
        };
        assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
    }
}
