//! Pure-rust reference implementation of the TinyDet detector — the same
//! analytic math as `python/compile/model.py`, written directly.
//!
//! Two uses:
//! * cross-layer validation: `rust/tests/runtime_hlo.rs` asserts this
//!   matches the HLO executables to float tolerance, closing the loop
//!   python-oracle ↔ Pallas kernel ↔ HLO ↔ rust;
//! * a fast detector for large parameter sweeps where the PJRT round-trip
//!   would dominate (never used for reported throughput numbers — those
//!   always come from the real executables).

/// Reusable intermediate buffers for the native detector — one per
/// thread lets the steady-state inference path run allocation-free
/// (`rust/tests/hotpath_alloc.rs`); the buffers grow to the frame's
/// working-set size on first use and are fully overwritten per call.
#[derive(Debug, Default)]
pub struct DetectScratch {
    opp: Vec<f32>,
    sum1: Vec<f32>,
    blur: Vec<f32>,
    dense: Vec<f32>,
}

impl DetectScratch {
    pub fn new() -> DetectScratch {
        DetectScratch::default()
    }
}

/// Clear and zero-fill a scratch vector to `n` without shrinking its
/// capacity — allocation-free once warm.
fn reset(buf: &mut Vec<f32>, n: usize) {
    buf.clear();
    buf.resize(n, 0.0);
}

/// Full-frame native detector: HWC f32 frame → (cells_h × cells_w) grid.
///
/// Allocating convenience wrapper over [`detect_full_into`].
pub fn detect_full(frame: &[f32], h: usize, w: usize) -> Vec<f32> {
    let mut scratch = DetectScratch::default();
    let mut out = Vec::new();
    detect_full_into(frame, h, w, &mut scratch, &mut out);
    out
}

/// Full-frame native detector writing the grid into `out` (cleared and
/// overwritten), with every intermediate in `scratch`.
///
/// Pipeline (identical to model.py's analytic weights):
///   pad 3 → conv1 = six color-opponency half-differences (center tap)
///         → conv2 = per-channel 3×3 box blur
///         → conv3 = relu(1.5 · Σ opponency − 0.15) (center tap)
///         → head = channel 0 → 16×16 mean pool.
pub fn detect_full_into(
    frame: &[f32],
    h: usize,
    w: usize,
    scratch: &mut DetectScratch,
    out: &mut Vec<f32>,
) {
    assert_eq!(frame.len(), h * w * 3);
    // padded geometry: x is (h+6, w+6), conv1 out (h+4, w+4),
    // conv2 out (h+2, w+2), conv3 out (h, w)
    let pw = w + 6;
    let ph = h + 6;
    let px = |y: usize, x: usize, c: usize| -> f32 {
        // padded read: 3px zero border
        if y < 3 || x < 3 || y >= ph - 3 || x >= pw - 3 {
            0.0
        } else {
            frame[((y - 3) * w + (x - 3)) * 3 + c]
        }
    };
    // conv1: opponency channels at (h+4, w+4); center tap of 3x3 VALID is
    // input(y+1, x+1)
    let c1w = w + 4;
    let c1h = h + 4;
    let opp = &mut scratch.opp;
    reset(opp, c1h * c1w * 6);
    for y in 0..c1h {
        for x in 0..c1w {
            let r = px(y + 1, x + 1, 0);
            let g = px(y + 1, x + 1, 1);
            let b = px(y + 1, x + 1, 2);
            let o = &mut opp[(y * c1w + x) * 6..(y * c1w + x) * 6 + 6];
            o[0] = (r - g).max(0.0);
            o[1] = (g - r).max(0.0);
            o[2] = (g - b).max(0.0);
            o[3] = (b - g).max(0.0);
            o[4] = (b - r).max(0.0);
            o[5] = (r - b).max(0.0);
        }
    }
    // conv2: per-channel box blur, VALID -> (h+2, w+2); we only need the
    // channel *sum* downstream, so blur the sum (linearity).
    let sum1 = &mut scratch.sum1;
    reset(sum1, c1h * c1w);
    for i in 0..c1h * c1w {
        sum1[i] = opp[i * 6..i * 6 + 6].iter().sum();
    }
    let c2w = w + 2;
    let c2h = h + 2;
    let blur = &mut scratch.blur;
    reset(blur, c2h * c2w);
    for y in 0..c2h {
        for x in 0..c2w {
            let mut acc = 0.0;
            for dy in 0..3 {
                for dx in 0..3 {
                    acc += sum1[(y + dy) * c1w + x + dx];
                }
            }
            blur[y * c2w + x] = acc / 9.0;
        }
    }
    // conv3 center tap + head: score(y, x) = relu(1.5·blur(y+1, x+1) − 0.15)
    // then 16x16 mean pool
    let cells_h = h / 16;
    let cells_w = w / 16;
    reset(out, cells_h * cells_w);
    for cy in 0..cells_h {
        for cx in 0..cells_w {
            let mut acc = 0.0;
            for iy in 0..16 {
                for ix in 0..16 {
                    let y = cy * 16 + iy;
                    let x = cx * 16 + ix;
                    let v = 1.5 * blur[(y + 1) * c2w + x + 1] - 0.15;
                    acc += v.max(0.0);
                }
            }
            out[cy * cells_w + cx] = acc / 256.0;
        }
    }
}

/// RoI-restricted native detector: the dense grid with non-active blocks
/// zeroed (equivalent to the HLO RoI variant by the block-locality of the
/// conv stack — validated in tests).
///
/// Allocating convenience wrapper over [`detect_roi_into`].
pub fn detect_roi(
    frame: &[f32],
    h: usize,
    w: usize,
    blocks: &[i32],
    block_px: usize,
    grid_bw: usize,
) -> Vec<f32> {
    let mut scratch = DetectScratch::default();
    let mut out = Vec::new();
    detect_roi_into(frame, h, w, blocks, block_px, grid_bw, &mut scratch, &mut out);
    out
}

/// [`detect_roi`] writing into `out` with every intermediate — including
/// the dense grid the RoI restriction copies from — in `scratch`.
#[allow(clippy::too_many_arguments)]
pub fn detect_roi_into(
    frame: &[f32],
    h: usize,
    w: usize,
    blocks: &[i32],
    block_px: usize,
    grid_bw: usize,
    scratch: &mut DetectScratch,
    out: &mut Vec<f32>,
) {
    // the dense grid lives in the scratch (taken out around the inner
    // call so `scratch` and the destination never alias)
    let mut dense = std::mem::take(&mut scratch.dense);
    detect_full_into(frame, h, w, scratch, &mut dense);
    let cells_w = w / 16;
    let cells_h = h / 16;
    let cpb = block_px / 16;
    reset(out, dense.len());
    for &b in blocks {
        if b < 0 {
            continue;
        }
        let by = b as usize / grid_bw;
        let bx = b as usize % grid_bw;
        for cy in 0..cpb {
            for cx in 0..cpb {
                let (gy, gx) = (by * cpb + cy, bx * cpb + cx);
                if gy < cells_h && gx < cells_w {
                    out[gy * cells_w + gx] = dense[gy * cells_w + gx];
                }
            }
        }
    }
    scratch.dense = dense;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gray_frame(h: usize, w: usize, level: f32) -> Vec<f32> {
        vec![level; h * w * 3]
    }

    #[test]
    fn gray_frame_is_silent() {
        let grid = detect_full(&gray_frame(192, 320, 0.45), 192, 320);
        assert!(grid.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn saturated_patch_lights_up() {
        let (h, w) = (192, 320);
        let mut frame = gray_frame(h, w, 0.45);
        // a red 32x48 "vehicle" at (64, 128)
        for y in 64..96 {
            for x in 128..176 {
                let i = (y * w + x) * 3;
                frame[i] = 0.85;
                frame[i + 1] = 0.15;
                frame[i + 2] = 0.12;
            }
        }
        let grid = detect_full(&frame, h, w);
        let cells_w = w / 16;
        // interior cell of the patch
        let v = grid[(64 / 16 + 1) * cells_w + 128 / 16 + 1];
        assert!(v > 0.25, "interior cell too weak: {v}");
        assert_eq!(grid[0], 0.0);
    }

    #[test]
    fn roi_restriction_zeroes_inactive_blocks() {
        let (h, w) = (192, 320);
        let mut frame = gray_frame(h, w, 0.45);
        for y in 0..32 {
            for x in 0..32 {
                let i = (y * w + x) * 3;
                frame[i] = 0.1;
                frame[i + 1] = 0.7;
                frame[i + 2] = 0.2;
            }
        }
        let dense = detect_full(&frame, h, w);
        let roi = detect_roi(&frame, h, w, &[0], 32, 10);
        let cells_w = w / 16;
        // block 0 cells match dense
        for cy in 0..2 {
            for cx in 0..2 {
                assert_eq!(roi[cy * cells_w + cx], dense[cy * cells_w + cx]);
            }
        }
        // a cell outside block 0 is zeroed even if dense had content there
        assert_eq!(roi[5 * cells_w + 9], 0.0);
    }

    #[test]
    fn black_frame_is_silent() {
        let grid = detect_full(&gray_frame(192, 320, 0.0), 192, 320);
        assert!(grid.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn into_variants_match_allocating_api_across_reuses() {
        let (h, w) = (192, 320);
        let mut frame = gray_frame(h, w, 0.45);
        for y in 64..96 {
            for x in 128..176 {
                let i = (y * w + x) * 3;
                frame[i] = 0.85;
                frame[i + 1] = 0.15;
                frame[i + 2] = 0.12;
            }
        }
        let dense = detect_full(&frame, h, w);
        let roi = detect_roi(&frame, h, w, &[0, 14], 32, 10);
        let mut scratch = DetectScratch::new();
        let mut out = Vec::new();
        // repeated calls through one scratch must keep matching (stale
        // buffer contents must never leak into the next grid)
        for _ in 0..2 {
            detect_full_into(&frame, h, w, &mut scratch, &mut out);
            assert_eq!(out, dense);
            detect_roi_into(&frame, h, w, &[0, 14], 32, 10, &mut scratch, &mut out);
            assert_eq!(out, roi);
        }
    }
}
