//! The evaluated methods (§5.2 ablations + §5.4 integrations) and the
//! flags that steer the offline and online phases.

/// The evaluated methods (§5.2 ablations + §5.4 integrations).
#[derive(Debug, Clone, PartialEq)]
pub enum Method {
    /// Everything off: full H.264 streams + off-the-shelf detector.
    Baseline,
    /// Filters ② off, rest of CrossRoI on.
    NoFilters,
    /// Tile grouping ⑤ off.
    NoMerging,
    /// RoI-based inference ⑥ off (dense detector on cropped frames).
    NoRoiInf,
    /// The full system.
    CrossRoi,
    /// Frame filtering only, with an accuracy target.
    Reducto(f64),
    /// CrossRoI + frame filtering (Fig. 12).
    CrossRoiReducto(f64),
}

impl Method {
    pub fn name(&self) -> String {
        match self {
            Method::Baseline => "Baseline".into(),
            Method::NoFilters => "No-Filters".into(),
            Method::NoMerging => "No-Merging".into(),
            Method::NoRoiInf => "No-RoIInf".into(),
            Method::CrossRoi => "CrossRoI".into(),
            Method::Reducto(t) => format!("Reducto@{t:.2}"),
            Method::CrossRoiReducto(t) => format!("CrossRoI-Reducto@{t:.2}"),
        }
    }

    /// Does the offline phase compute RoI masks?
    pub fn uses_roi_masks(&self) -> bool {
        !matches!(self, Method::Baseline | Method::Reducto(_))
    }

    /// Are the tandem statistical filters applied?
    pub fn uses_filters(&self) -> bool {
        self.uses_roi_masks() && !matches!(self, Method::NoFilters)
    }

    /// Is the tile grouping algorithm applied?
    pub fn uses_merging(&self) -> bool {
        self.uses_roi_masks() && !matches!(self, Method::NoMerging)
    }

    /// Is the SBNet RoI inference variant used?
    pub fn uses_roi_inference(&self) -> bool {
        matches!(
            self,
            Method::NoFilters | Method::NoMerging | Method::CrossRoi | Method::CrossRoiReducto(_)
        )
    }

    /// Frame-filter accuracy target, if any.
    pub fn reducto_target(&self) -> Option<f64> {
        match self {
            Method::Reducto(t) | Method::CrossRoiReducto(t) => Some(*t),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The full flag matrix for all 7 variants:
    /// (method, roi_masks, filters, merging, roi_inference, reducto_target).
    #[test]
    fn flag_matrix_all_variants() {
        let t = 0.9;
        let matrix: [(Method, bool, bool, bool, bool, Option<f64>); 7] = [
            (Method::Baseline, false, false, false, false, None),
            (Method::NoFilters, true, false, true, true, None),
            (Method::NoMerging, true, true, false, true, None),
            (Method::NoRoiInf, true, true, true, false, None),
            (Method::CrossRoi, true, true, true, true, None),
            (Method::Reducto(t), false, false, false, false, Some(t)),
            (Method::CrossRoiReducto(t), true, true, true, true, Some(t)),
        ];
        for (m, masks, filters, merging, roi_inf, target) in matrix {
            assert_eq!(m.uses_roi_masks(), masks, "{}: uses_roi_masks", m.name());
            assert_eq!(m.uses_filters(), filters, "{}: uses_filters", m.name());
            assert_eq!(m.uses_merging(), merging, "{}: uses_merging", m.name());
            assert_eq!(m.uses_roi_inference(), roi_inf, "{}: uses_roi_inference", m.name());
            assert_eq!(m.reducto_target(), target, "{}: reducto_target", m.name());
        }
    }

    /// Filters/merging imply RoI masks: no variant may enable a dependent
    /// module while the masks themselves are off.
    #[test]
    fn dependent_flags_require_masks() {
        for m in [
            Method::Baseline,
            Method::NoFilters,
            Method::NoMerging,
            Method::NoRoiInf,
            Method::CrossRoi,
            Method::Reducto(0.8),
            Method::CrossRoiReducto(0.8),
        ] {
            if !m.uses_roi_masks() {
                assert!(!m.uses_filters(), "{}", m.name());
                assert!(!m.uses_merging(), "{}", m.name());
            }
        }
    }

    #[test]
    fn names_are_distinct_and_encode_targets() {
        let names: Vec<String> = [
            Method::Baseline,
            Method::NoFilters,
            Method::NoMerging,
            Method::NoRoiInf,
            Method::CrossRoi,
            Method::Reducto(0.9),
            Method::CrossRoiReducto(0.95),
        ]
        .iter()
        .map(|m| m.name())
        .collect();
        let unique: std::collections::HashSet<&String> = names.iter().collect();
        assert_eq!(unique.len(), names.len(), "duplicate method names: {names:?}");
        assert_eq!(names[5], "Reducto@0.90");
        assert_eq!(names[6], "CrossRoI-Reducto@0.95");
    }
}
