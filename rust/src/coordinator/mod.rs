//! The L3 coordinator: CrossRoI's two-phase workflow (§4.1).
//!
//! [`offline`] runs modules ①–④ (ReID → tandem filters → region
//! association → RoI optimization → tile grouping) over the profile
//! window and produces each camera's plan; [`online`] drives the
//! streaming pipeline (⑤ crop/group/encode/stream, ⑥ RoI-CNN inference)
//! over the evaluation window, with real measured compute and a
//! discrete-event network/queueing model, and scores the unique-vehicle
//! query.  [`metrics`] defines the report every bench prints.

pub mod metrics;
pub mod offline;
pub mod online;

pub use metrics::{LatencyBreakdown, MethodReport};
pub use offline::{build_plan, OfflinePlan};
pub use online::{
    baseline_reference, run_ablation, run_method, Infer, Method, NativeInfer, RuntimeInfer,
};
