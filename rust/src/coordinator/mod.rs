//! The L3 coordinator: CrossRoI's two-phase workflow (§4.1).
//!
//! The offline planner lives in [`crate::offline`] (Profile → [Shard] →
//! Filter → Associate → Solve → Group over the profile window, producing
//! each camera's plan with a per-stage [`PlanReport`]; the deprecated
//! `coordinator::offline` re-export shim is gone — spell the planner
//! path as `crate::offline`).  [`online`] orchestrates
//! the staged streaming pipeline in [`crate::pipeline`] (⑤ per-camera
//! crop/group/encode workers, ⑥ merged batched RoI-CNN inference) over
//! the evaluation window — with real measured compute, a discrete-event
//! network/queueing replay, and optional continuous re-profiling
//! (DESIGN.md §7) — and scores the unique-vehicle query.  [`metrics`]
//! defines the report every bench prints.

pub mod method;
pub mod metrics;
pub mod online;

pub use method::Method;
pub use metrics::{LatencyBreakdown, MethodReport};
pub use crate::offline::{
    build_plan, build_plan_from_stream, build_plan_with, OfflineOptions, OfflinePlan,
    PlanReport, ShardMode, ShardReport, SolverKind,
};
pub use online::{
    baseline_reference, baseline_reference_with, run_ablation, run_ablation_with, run_method,
    run_method_with,
};

// Inference backends live with the pipeline's inference stage; re-exported
// here because they are part of the coordinator's public entry points.
#[cfg(feature = "pjrt")]
pub use crate::pipeline::RuntimeInfer;
pub use crate::pipeline::{Infer, NativeInfer};
