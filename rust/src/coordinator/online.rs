//! Online phase (§4.1.2): cameras crop → (optionally frame-filter) →
//! encode → stream; the server reassembles, runs (RoI-)CNN inference and
//! answers the unique-vehicle query.
//!
//! Compute costs (encode, inference) are **measured** on this host; the
//! transport and queueing behaviour (shared 30 Mbps link, segment
//! queueing, FIFO server) is replayed on the discrete-event engine with
//! those measured service times — see DESIGN.md §3 on the testbed
//! substitution.

use std::collections::HashSet;
use std::time::Instant;

use anyhow::Result;

use crate::codec::SegmentEncoder;
use crate::config::SystemConfig;
use crate::coordinator::metrics::{LatencyBreakdown, MethodReport};
use crate::coordinator::offline::{build_plan, OfflinePlan};
use crate::net::{Des, SharedLink};
use crate::query;
use crate::reducto::{self, ReductoFilter};
use crate::runtime::postproc::decode_objectness;
use crate::runtime::Runtime;
use crate::sim::render::Frame;
use crate::sim::Scenario;
use crate::util::geometry::IRect;
use crate::util::stats;

/// The evaluated methods (§5.2 ablations + §5.4 integrations).
#[derive(Debug, Clone, PartialEq)]
pub enum Method {
    /// Everything off: full H.264 streams + off-the-shelf detector.
    Baseline,
    /// Filters ② off, rest of CrossRoI on.
    NoFilters,
    /// Tile grouping ⑤ off.
    NoMerging,
    /// RoI-based inference ⑥ off (dense detector on cropped frames).
    NoRoiInf,
    /// The full system.
    CrossRoi,
    /// Frame filtering only, with an accuracy target.
    Reducto(f64),
    /// CrossRoI + frame filtering (Fig. 12).
    CrossRoiReducto(f64),
}

impl Method {
    pub fn name(&self) -> String {
        match self {
            Method::Baseline => "Baseline".into(),
            Method::NoFilters => "No-Filters".into(),
            Method::NoMerging => "No-Merging".into(),
            Method::NoRoiInf => "No-RoIInf".into(),
            Method::CrossRoi => "CrossRoI".into(),
            Method::Reducto(t) => format!("Reducto@{t:.2}"),
            Method::CrossRoiReducto(t) => format!("CrossRoI-Reducto@{t:.2}"),
        }
    }

    /// Does the offline phase compute RoI masks?
    pub fn uses_roi_masks(&self) -> bool {
        !matches!(self, Method::Baseline | Method::Reducto(_))
    }

    /// Are the tandem statistical filters applied?
    pub fn uses_filters(&self) -> bool {
        self.uses_roi_masks() && !matches!(self, Method::NoFilters)
    }

    /// Is the tile grouping algorithm applied?
    pub fn uses_merging(&self) -> bool {
        self.uses_roi_masks() && !matches!(self, Method::NoMerging)
    }

    /// Is the SBNet RoI inference variant used?
    pub fn uses_roi_inference(&self) -> bool {
        matches!(self, Method::NoFilters | Method::NoMerging | Method::CrossRoi
            | Method::CrossRoiReducto(_))
    }

    /// Frame-filter accuracy target, if any.
    pub fn reducto_target(&self) -> Option<f64> {
        match self {
            Method::Reducto(t) | Method::CrossRoiReducto(t) => Some(*t),
            _ => None,
        }
    }
}

/// Inference backend abstraction: the real PJRT runtime in benches and
/// examples, the native reference in fast tests.
pub trait Infer {
    /// Run the detector; `blocks = None` means the dense variant.
    /// Returns the objectness grid and the measured inference seconds.
    fn infer(&self, frame: &[f32], blocks: Option<&[i32]>) -> Result<(Vec<f32>, f64)>;

    /// Total detector blocks (for the dense-fallback policy).
    fn n_blocks(&self) -> usize {
        60
    }
}

/// Real PJRT-backed inference.
pub struct RuntimeInfer<'a>(pub &'a Runtime);

impl Infer for RuntimeInfer<'_> {
    fn infer(&self, frame: &[f32], blocks: Option<&[i32]>) -> Result<(Vec<f32>, f64)> {
        let t0 = Instant::now();
        let grid = match blocks {
            None => self.0.infer_full(frame)?,
            Some(b) => self.0.infer_roi(frame, b)?.0,
        };
        Ok((grid, t0.elapsed().as_secs_f64()))
    }

    fn n_blocks(&self) -> usize {
        self.0.contract.n_blocks
    }
}

/// Native reference inference (tests / fast sweeps; never used for
/// reported throughput numbers).
pub struct NativeInfer;

impl Infer for NativeInfer {
    fn infer(&self, frame: &[f32], blocks: Option<&[i32]>) -> Result<(Vec<f32>, f64)> {
        let t0 = Instant::now();
        let grid = match blocks {
            None => crate::runtime::native::detect_full(frame, 192, 320),
            Some(b) => crate::runtime::native::detect_roi(frame, 192, 320, b, 32, 10),
        };
        Ok((grid, t0.elapsed().as_secs_f64()))
    }
}

/// When the RoI covers at least this fraction of blocks, fall back to the
/// dense detector (§4.4: "we load both RoI-YOLO and normal YOLO into GPU
/// and push large RoI-area videos to normal YOLO").  The threshold sits at
/// the measured crossover of the compiled variants: a mask needing the
/// K=60 capacity runs slower than dense, so only masks that fit K≤32
/// (≤ 32/60 ≈ 53 % coverage) take the SBNet path (see the
/// `sbnet_crossover` bench).
pub const DENSE_FALLBACK_FRACTION: f64 = 0.55;

// ---------------------------------------------------------------------------

/// Per-segment record produced by the compute pass and consumed by the DES.
struct SegmentRecord {
    cam: usize,
    /// Virtual time (s, eval-window origin) when the segment's last frame
    /// was captured.
    capture_end: f64,
    bytes: usize,
    encode_secs: f64,
    /// (local frame index, capture time, inference seconds) per kept frame.
    frames: Vec<(usize, f64, f64)>,
}

/// DES events of the online pipeline.
enum Ev {
    Captured(usize),
    EncodeDone(usize),
    Arrived(usize),
}

/// Run one method over the scenario's evaluation window.
///
/// `reference` is the per-frame correct vehicle sets (the Baseline run's
/// results, per §5.2.1); `None` falls back to simulator ground truth.
pub fn run_method(
    scenario: &Scenario,
    sys: &SystemConfig,
    infer: &dyn Infer,
    method: &Method,
    reference: Option<&[HashSet<u32>]>,
) -> Result<MethodReport> {
    Ok(run_method_inner(scenario, sys, infer, method, reference)?.0)
}

/// Like [`run_method`], but also returns the per-frame reported vehicle
/// sets (used to build the Baseline reference).
fn run_method_inner(
    scenario: &Scenario,
    sys: &SystemConfig,
    infer: &dyn Infer,
    method: &Method,
    reference: Option<&[HashSet<u32>]>,
) -> Result<(MethodReport, Vec<HashSet<u32>>)> {
    let cfg = &scenario.cfg;
    let fps = cfg.fps;
    let n_cams = scenario.cameras.len();
    let eval = scenario.eval_range();
    let n_frames = eval.len();
    let frames_per_segment = ((sys.segment_secs * fps).round() as usize).max(1);

    // ---- offline phase ----
    let plan: OfflinePlan = build_plan(scenario, cfg, sys, method);
    let reducto_filter = match method.reducto_target() {
        None => None,
        Some(target) => {
            let regions: Vec<Vec<IRect>> = plan.groups.clone();
            Some(if target >= 1.0 {
                ReductoFilter::disabled(n_cams)
            } else {
                ReductoFilter::profile(
                    scenario,
                    &regions,
                    scenario.profile_range(),
                    frames_per_segment,
                    target,
                )
            })
        }
    };

    // which cameras use the RoI inference variant
    let use_roi: Vec<bool> = (0..n_cams)
        .map(|c| {
            method.uses_roi_inference()
                && (plan.blocks[c].len() as f64)
                    < DENSE_FALLBACK_FRACTION * infer.n_blocks() as f64
        })
        .collect();

    // ---- compute pass: render, filter, encode, infer (all measured) ----
    let renderer = scenario.renderer();
    let mut segments: Vec<SegmentRecord> = Vec::new();
    // per (cam, local frame): Some(vehicles) for inferred frames
    let mut cam_frame_sets: Vec<Vec<Option<HashSet<u32>>>> =
        vec![vec![None; n_frames]; n_cams];
    let mut frames_reduced = 0usize;
    let mut encode_secs_per_cam = vec![0.0f64; n_cams];
    let mut encoded_frames_per_cam = vec![0usize; n_cams];
    let mut infer_secs_total = 0.0f64;
    let mut infer_count = 0usize;
    let mut bytes_per_cam = vec![0u64; n_cams];

    for cam in 0..n_cams {
        let mut enc = SegmentEncoder::new(&plan.groups[cam], sys.qp);
        let mut prev_frame: Option<Frame> = None;
        let mut local = 0usize;
        while local < n_frames {
            let seg_frames: Vec<usize> =
                (local..(local + frames_per_segment).min(n_frames)).collect();
            // render + frame-filter decisions
            let mut kept: Vec<(usize, Frame)> = Vec::new();
            for (k, &lf) in seg_frames.iter().enumerate() {
                let abs = eval.start + lf;
                let frame = renderer.render(cam, abs);
                let keep = match (&reducto_filter, &prev_frame) {
                    (None, _) => true,
                    (Some(_), None) => true,
                    (Some(f), Some(prev)) => {
                        if k == 0 {
                            true // segment head is always sent
                        } else {
                            let d = reducto::frame_diff(prev, &frame, &plan.groups[cam]);
                            d > f.thresholds[cam]
                        }
                    }
                };
                prev_frame = Some(frame.clone());
                if keep {
                    kept.push((lf, frame));
                } else {
                    frames_reduced += 1;
                }
            }
            // encode the kept frames (measured)
            let enc_frames: Vec<Frame> = kept.iter().map(|(_, f)| f.clone()).collect();
            let t0 = Instant::now();
            let encoded = enc.encode_segment(&enc_frames);
            let enc_secs = t0.elapsed().as_secs_f64();
            encode_secs_per_cam[cam] += enc_secs;
            encoded_frames_per_cam[cam] += enc_frames.len();
            bytes_per_cam[cam] += encoded.bytes as u64;

            // server-side inference on the kept (masked) frames (measured)
            let mut frame_recs = Vec::with_capacity(kept.len());
            for (lf, frame) in &kept {
                let masked = frame.masked_keep(&plan.groups[cam]);
                let pixels = masked.to_f32();
                let blocks_arg = if use_roi[cam] { Some(plan.blocks[cam].as_slice()) } else { None };
                let (grid, secs) = infer.infer(&pixels, blocks_arg)?;
                infer_secs_total += secs;
                infer_count += 1;
                let dets = decode_objectness(&grid, 12, 20, 16, sys.objectness_threshold);
                let abs = eval.start + lf;
                let matched = query::match_detections(&dets, scenario.detections(cam, abs));
                cam_frame_sets[cam][*lf] = Some(matched);
                frame_recs.push((*lf, (*lf as f64 + 1.0) / fps, secs));
            }
            segments.push(SegmentRecord {
                cam,
                capture_end: (*seg_frames.last().unwrap() as f64 + 1.0) / fps,
                bytes: encoded.bytes,
                encode_secs: enc_secs,
                frames: frame_recs,
            });
            local += frames_per_segment;
        }
    }

    // ---- query scoring (carry-over for filtered frames) ----
    let mut reported: Vec<HashSet<u32>> = vec![HashSet::new(); n_frames];
    for cam in 0..n_cams {
        let mut last: HashSet<u32> = HashSet::new();
        for lf in 0..n_frames {
            if let Some(s) = &cam_frame_sets[cam][lf] {
                last = s.clone();
            }
            for &v in &last {
                reported[lf].insert(v);
            }
        }
    }
    let gt_sets: Vec<HashSet<u32>>;
    let reference: &[HashSet<u32>] = match reference {
        Some(r) => r,
        None => {
            gt_sets = (0..n_frames)
                .map(|lf| scenario.unique_visible(eval.start + lf).into_iter().collect())
                .collect();
            &gt_sets
        }
    };
    let (acc, missed) = query::accuracy(reference, &reported);

    // ---- DES replay: transport + queueing with measured service times ----
    let mut order: Vec<usize> = (0..segments.len()).collect();
    order.sort_by(|&a, &b| segments[a].capture_end.partial_cmp(&segments[b].capture_end).unwrap());
    let mut des: Des<Ev> = Des::new();
    for &si in &order {
        des.at(segments[si].capture_end, Ev::Captured(si));
    }
    let mut link = SharedLink::new(sys.bandwidth_mbps, sys.rtt_ms);
    let mut cam_free = vec![0.0f64; n_cams];
    let mut enc_done_at = vec![0.0f64; segments.len()];
    let mut arrived_at = vec![0.0f64; segments.len()];
    let mut server_free = 0.0f64;
    let mut cam_lat = Vec::new();
    let mut net_lat = Vec::new();
    let mut srv_lat = Vec::new();
    let mut tot_lat = Vec::new();
    while let Some((now, ev)) = des.pop() {
        match ev {
            Ev::Captured(si) => {
                let s = &segments[si];
                let start = now.max(cam_free[s.cam]);
                let done = start + s.encode_secs;
                cam_free[s.cam] = done;
                enc_done_at[si] = done;
                des.at(done, Ev::EncodeDone(si));
            }
            Ev::EncodeDone(si) => {
                let arrival = link.transfer(now, segments[si].bytes);
                arrived_at[si] = arrival;
                des.at(arrival, Ev::Arrived(si));
            }
            Ev::Arrived(si) => {
                let s = &segments[si];
                for &(_, capture, secs) in &s.frames {
                    let start = server_free.max(now);
                    let done = start + secs;
                    server_free = done;
                    cam_lat.push(enc_done_at[si] - capture);
                    net_lat.push(arrived_at[si] - enc_done_at[si]);
                    srv_lat.push(done - arrived_at[si]);
                    tot_lat.push(done - capture);
                }
            }
        }
    }

    // ---- report ----
    let eval_secs = n_frames as f64 / fps;
    let network_mbps_per_cam: Vec<f64> =
        bytes_per_cam.iter().map(|&b| b as f64 * 8.0 / 1e6 / eval_secs).collect();
    let camera_fps: Vec<f64> = (0..n_cams)
        .map(|c| {
            if encode_secs_per_cam[c] > 0.0 {
                encoded_frames_per_cam[c] as f64 / encode_secs_per_cam[c]
            } else {
                f64::INFINITY
            }
        })
        .collect();
    let report = MethodReport {
        method: method.name(),
        accuracy: acc,
        missed_per_frame: missed,
        total_appearances: query::total_appearances(reference),
        network_mbps_total: network_mbps_per_cam.iter().sum(),
        network_mbps_per_cam,
        bytes_total: bytes_per_cam.iter().sum(),
        server_hz: if infer_secs_total > 0.0 { infer_count as f64 / infer_secs_total } else { 0.0 },
        camera_fps: stats::mean(&camera_fps),
        latency: LatencyBreakdown {
            camera: stats::mean(&cam_lat),
            network: stats::mean(&net_lat),
            server: stats::mean(&srv_lat),
        },
        latency_p95: stats::percentile(&tot_lat, 95.0),
        frames_reduced,
        frames_total: n_frames * n_cams,
        mask_tiles: plan.masks.total_size(),
        mask_coverage: stats::mean(
            &(0..n_cams).map(|c| plan.masks.coverage(c)).collect::<Vec<_>>(),
        ),
        regions_per_cam: plan.groups.iter().map(|g| g.len()).collect(),
        offline_seconds: plan.seconds,
    };
    Ok((report, reported))
}

/// Run a list of methods with the Baseline's results as the shared
/// accuracy reference (§5.2.1).  Baseline is always run first.
pub fn run_ablation(
    scenario: &Scenario,
    sys: &SystemConfig,
    infer: &dyn Infer,
    methods: &[Method],
) -> Result<Vec<MethodReport>> {
    // §5.2.1: the reference is the Baseline method's detections fused with
    // the ReID ground truth.  We run Baseline first and collect its
    // per-frame reports as the reference, so Baseline scores 1.0 by
    // construction and every other method is scored against what the
    // full-data pipeline can actually detect.
    let (reference, baseline) = baseline_reference(scenario, sys, infer)?;
    let mut out = Vec::new();
    for m in methods {
        if *m == Method::Baseline {
            out.push(baseline.clone());
        } else {
            out.push(run_method(scenario, sys, infer, m, Some(&reference))?);
        }
    }
    Ok(out)
}

/// Run Baseline and return (its per-frame reported sets, its report scored
/// against itself — i.e. accuracy 1.0, zero misses, per §5.2.1).
pub fn baseline_reference(
    scenario: &Scenario,
    sys: &SystemConfig,
    infer: &dyn Infer,
) -> Result<(Vec<HashSet<u32>>, MethodReport)> {
    let (mut report, reported) =
        run_method_inner(scenario, sys, infer, &Method::Baseline, None)?;
    report.accuracy = 1.0;
    report.missed_per_frame = vec![0; reported.len()];
    report.total_appearances = query::total_appearances(&reported);
    Ok((reported, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    #[test]
    fn method_flags() {
        assert!(!Method::Baseline.uses_roi_masks());
        assert!(!Method::Reducto(0.9).uses_roi_masks());
        assert!(Method::NoFilters.uses_roi_masks());
        assert!(!Method::NoFilters.uses_filters());
        assert!(!Method::NoMerging.uses_merging());
        assert!(Method::NoMerging.uses_roi_inference());
        assert!(!Method::NoRoiInf.uses_roi_inference());
        assert!(Method::CrossRoi.uses_filters());
        assert_eq!(Method::CrossRoiReducto(0.9).reducto_target(), Some(0.9));
        assert_eq!(Method::CrossRoi.reducto_target(), None);
    }

    // Heavier end-to-end coverage lives in rust/tests/online_pipeline.rs;
    // this smoke test keeps the module independently verified.
    #[test]
    fn smoke_baseline_native() {
        let mut cfg = Config::test_small();
        cfg.scenario.profile_secs = 6.0;
        cfg.scenario.eval_secs = 4.0;
        let sc = Scenario::build(&cfg.scenario);
        let rep = run_method(&sc, &cfg.system, &NativeInfer, &Method::Baseline, None).unwrap();
        let eval_frames = (cfg.scenario.eval_secs * cfg.scenario.fps).round() as usize;
        assert_eq!(rep.frames_total, eval_frames * 5);
        assert!(rep.network_mbps_total > 0.0);
        assert!(rep.server_hz > 0.0);
        assert!(rep.latency.total() > 0.0);
        assert!(rep.accuracy > 0.5, "baseline accuracy {}", rep.accuracy);
    }
}
