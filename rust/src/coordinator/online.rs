//! Online phase (§4.1.2): cameras crop → (optionally frame-filter) →
//! encode → stream; the server reassembles, runs (RoI-)CNN inference and
//! answers the unique-vehicle query.
//!
//! This module is orchestration only: it builds the offline plan, wires
//! one [`CameraStages`] chain per camera plus the server-side batched
//! inference stage, and hands scheduling to [`crate::pipeline`].  Compute
//! costs (encode, inference) are **measured** on this host; the transport
//! and queueing behaviour (shared 30 Mbps link, segment queueing, FIFO
//! server) is replayed on the discrete-event engine with those measured
//! service times — see DESIGN.md §3 on the testbed substitution.
//!
//! With a [`crate::pipeline::ReplanPolicy`] other than `Never`
//! (`--replan-every` / `--replan-drift`), the run also installs
//! continuous re-profiling (DESIGN.md §7): an
//! [`crate::offline::Replanner`] slides the profile window beside the
//! stage workers and the pipeline swaps masks at epoch boundaries; the
//! DES replay timestamps each executed re-plan into the report.

use std::collections::HashSet;
use std::sync::Arc;

use anyhow::Result;

use crate::config::SystemConfig;
use crate::coordinator::method::Method;
use crate::coordinator::metrics::{LatencyBreakdown, MethodReport};
use crate::offline::replan::{RepairRecord, Replanner, ReplanRecord};
use crate::offline::{build_plan_with, OfflinePlan};
use crate::pipeline::{
    consolidation_active, run_pipeline_in, use_roi_path, Arena, BatchedInfer, CameraStages,
    CanvasTally, CarryOverQuery, CodecEncodeStage, DesTransport, FaultContext, FaultTimeline,
    FilterStage, Infer, LivenessMonitor, PassThroughFilter, PipelineOptions, PlanEpoch,
    PlanSchedule, QueryStage, ReductoFilterStage, ReplanContext, ReplanPolicy, SegmentLayout,
    SimCapture,
};
use crate::util::geometry::IRect;
use crate::query;
use crate::reducto::ReductoFilter;
use crate::sim::Scenario;
use crate::util::stats;

/// Run one method over the scenario's evaluation window with the default
/// pipeline schedule (one worker thread per camera).
///
/// `reference` is the per-frame correct vehicle sets (the Baseline run's
/// results, per §5.2.1); `None` falls back to simulator ground truth.
pub fn run_method(
    scenario: &Scenario,
    sys: &SystemConfig,
    infer: &dyn Infer,
    method: &Method,
    reference: Option<&[HashSet<u32>]>,
) -> Result<MethodReport> {
    Ok(run_method_with(scenario, sys, infer, method, reference, &PipelineOptions::default())?.0)
}

/// Like [`run_method`], but with explicit [`PipelineOptions`] (schedule +
/// cost model) and also returning the per-frame reported vehicle sets
/// (used to build the Baseline reference).
pub fn run_method_with(
    scenario: &Scenario,
    sys: &SystemConfig,
    infer: &dyn Infer,
    method: &Method,
    reference: Option<&[HashSet<u32>]>,
    opts: &PipelineOptions,
) -> Result<(MethodReport, Vec<HashSet<u32>>)> {
    let cfg = &scenario.cfg;
    let fps = cfg.fps;
    let n_cams = scenario.cameras.len();
    let eval = scenario.eval_range();
    let n_frames = eval.len();
    let frames_per_segment = ((sys.segment_secs * fps).round() as usize).max(1);

    // ---- offline phase ----
    let plan: OfflinePlan = build_plan_with(scenario, cfg, sys, method, &opts.offline)?;
    let reducto_filter = method.reducto_target().map(|target| {
        if target >= 1.0 {
            ReductoFilter::disabled(n_cams)
        } else {
            let profile = scenario.profile_range();
            ReductoFilter::profile(scenario, &plan.groups, profile, frames_per_segment, target)
        }
    });

    // which cameras use the RoI inference variant
    let use_roi: Vec<bool> = (0..n_cams)
        .map(|c| use_roi_path(method, plan.blocks[c].len(), infer.n_blocks()))
        .collect();

    // ---- staged compute pass: per-camera capture → filter → encode
    // workers feeding the merged, batched inference stage (all measured) ----
    let renderer = scenario.renderer();
    let layout = SegmentLayout { n_frames, frames_per_segment, fps };

    // continuous re-profiling: epoch schedule + sliding-window,
    // component-incremental re-planner (full-frame methods have no masks
    // to chase, so the policy is inert for them).  Epoch 0 carries the
    // offline-profiled Reducto thresholds; later epochs re-derive each
    // camera's threshold from the sliding window whenever a re-plan
    // changes its regions (DESIGN.md §8).
    // fault schedule resolved onto the segment grid.  Faults suppress
    // camera output for every method; plan *repair* additionally needs
    // masks and an epoch cadence, so with `--replan never` a masked
    // method synthesizes the default cadence in repair-only mode — the
    // planner wakes only at repair/rejoin epochs and carries every
    // other boundary by pointer.
    let has_faults = !cfg.faults.is_empty();
    let check_every = opts.replan.check_every().or_else(|| {
        (has_faults && method.uses_roi_masks()).then_some(ReplanPolicy::DEFAULT_CHECK_EVERY)
    });
    let faults: Option<Arc<FaultTimeline>> = has_faults.then(|| {
        // a dead camera's peers are its offline shard members (the
        // cameras whose constraints its coverage was traded against);
        // unsharded plans fall back to one fleet-wide component
        let components: Vec<Vec<usize>> = if plan.report.shards.is_empty() {
            vec![(0..n_cams).collect()]
        } else {
            plan.report.shards.iter().map(|s| s.cameras.clone()).collect()
        };
        Arc::new(FaultTimeline::new(
            &cfg.faults,
            n_cams,
            layout.n_segments(),
            frames_per_segment,
            fps,
            check_every.unwrap_or(ReplanPolicy::DEFAULT_CHECK_EVERY),
            eval.start,
            &components,
        ))
    });
    let replan_setup: Option<(PlanSchedule, Replanner<'_>)> =
        match (check_every, method.uses_roi_masks()) {
            (Some(check_every), true) => {
                let epoch0 = PlanEpoch::initial(
                    plan.groups.clone(),
                    plan.blocks.clone(),
                    use_roi.clone(),
                    reducto_filter.as_ref().map(|f| f.thresholds.clone()),
                    plan.masks.total_size(),
                );
                let schedule = PlanSchedule::new(layout.n_segments(), check_every, epoch0);
                let mut replanner = Replanner::new(
                    scenario,
                    sys,
                    method,
                    opts.offline,
                    opts.replan,
                    opts.replan_scope,
                    frames_per_segment,
                    &plan,
                    infer.n_blocks(),
                )
                .with_planner_threads(opts.planner_threads);
                if let Some(t) = &faults {
                    replanner = replanner.with_faults(Arc::clone(t));
                }
                Some((schedule, replanner))
            }
            _ => None,
        };

    let cams: Vec<CameraStages<'_>> = (0..n_cams)
        .map(|cam| {
            let regions = &plan.groups[cam];
            let filter: Box<dyn FilterStage + '_> = match &reducto_filter {
                None => Box::new(PassThroughFilter),
                Some(f) => Box::new(ReductoFilterStage::new(regions, f.thresholds[cam])),
            };
            CameraStages {
                capture: Box::new(SimCapture::new(&renderer, cam, eval.start)),
                filter,
                encode: Box::new(CodecEncodeStage::new(regions, sys.qp, opts.encode_cost)),
                mask: regions,
            }
        })
        .collect();
    // one buffer arena spans the whole run: camera-side frame/pixel
    // buffers and the server's inference-grid buffers all recycle here
    let arena = Arena::new();
    // cross-camera canvas consolidation (DESIGN.md §13): the route is a
    // pure function of plan + policy; the tally collects the per-batch
    // packing diagnostics
    let frame_px = plan.masks.tiling.frame_w as u64 * plan.masks.tiling.frame_h as u64;
    let canvas_tally = CanvasTally::default();
    let server = BatchedInfer {
        infer,
        scenario,
        blocks: &plan.blocks,
        use_roi: &use_roi,
        groups: &plan.groups,
        consolidate: opts.consolidate,
        canvas_tally: Some(&canvas_tally),
        schedule: replan_setup.as_ref().map(|(s, _)| s),
        objectness_threshold: sys.objectness_threshold,
        eval_start: eval.start,
        arena: Some(&arena),
        fault: faults.as_deref(),
    };
    let fault_ctx = faults.as_ref().map(|t| FaultContext {
        timeline: Arc::clone(t),
        full_frame: IRect::new(0, 0, plan.masks.tiling.frame_w, plan.masks.tiling.frame_h),
    });
    let out = run_pipeline_in(
        cams,
        &server,
        &layout,
        opts.parallelism,
        replan_setup
            .as_ref()
            .map(|(schedule, planner)| ReplanContext { schedule, planner }),
        fault_ctx.as_ref(),
        &arena,
    )?;
    let replan_records: Vec<ReplanRecord> =
        replan_setup.as_ref().map(|(_, r)| r.records()).unwrap_or_default();
    let repair_records: Vec<RepairRecord> =
        replan_setup.as_ref().map(|(_, r)| r.repair_records()).unwrap_or_default();
    let pool = replan_setup.as_ref().map(|(_, r)| r.pool_stats()).unwrap_or_default();

    // cross-check the segment-deadline liveness monitor against the
    // timeline that actually drove repair: every silence the DES replay
    // detects must be a segment the fault schedule explains
    if let Some(t) = &faults {
        let mut monitor = LivenessMonitor::new(n_cams, layout.n_segments(), sys.segment_secs);
        for s in &out.segments {
            monitor.observe(s.cam, s.seg, s.capture_end);
        }
        for sil in monitor.silences() {
            debug_assert!(
                t.down_seg(sil.cam, sil.seg),
                "liveness monitor flagged cam {} seg {} (deadline {:.2}s) but the fault \
                 timeline does not explain it",
                sil.cam,
                sil.seg,
                sil.deadline,
            );
        }
    }

    // ---- query scoring (carry-over for filtered frames) ----
    let reported = CarryOverQuery.fuse(&out.frame_sets, n_frames);
    let gt_sets: Vec<HashSet<u32>>;
    let reference: &[HashSet<u32>] = match reference {
        Some(r) => r,
        None => {
            gt_sets = (0..n_frames)
                .map(|lf| scenario.unique_visible(eval.start + lf).into_iter().collect())
                .collect();
            &gt_sets
        }
    };
    let (acc, missed) = query::accuracy(reference, &reported);

    // ---- DES replay: transport + queueing with measured service times;
    // executed re-plans are timestamped on the same virtual clock ----
    let executed: Vec<&ReplanRecord> =
        replan_records.iter().filter(|r| r.replanned).collect();
    let replan_events: Vec<(f64, f64)> =
        executed.iter().map(|r| (r.trigger_time, r.seconds)).collect();
    let (lat, replan_done_at) = DesTransport::new(sys.bandwidth_mbps, sys.rtt_ms)
        .replay_with_replans(n_cams, &out.segments, &replan_events);

    // ---- report (aggregated in canonical segment order) ----
    let mut bytes_per_cam = vec![0u64; n_cams];
    let mut encode_secs_per_cam = vec![0.0f64; n_cams];
    let mut encoded_frames_per_cam = vec![0usize; n_cams];
    let mut infer_secs_total = 0.0f64;
    let mut infer_count = 0usize;
    for s in &out.segments {
        bytes_per_cam[s.cam] += s.bytes as u64;
        encode_secs_per_cam[s.cam] += s.encode_secs;
        encoded_frames_per_cam[s.cam] += s.frames.len();
        for &(_, _, secs) in &s.frames {
            infer_secs_total += secs;
            infer_count += 1;
        }
    }
    let eval_secs = n_frames as f64 / fps;
    let network_mbps_per_cam: Vec<f64> =
        bytes_per_cam.iter().map(|&b| b as f64 * 8.0 / 1e6 / eval_secs).collect();
    let camera_fps: Vec<f64> = (0..n_cams)
        .map(|c| match encode_secs_per_cam[c] {
            s if s > 0.0 => encoded_frames_per_cam[c] as f64 / s,
            _ => f64::INFINITY,
        })
        .collect();
    let report = MethodReport {
        method: method.name(),
        accuracy: acc,
        missed_per_frame: missed,
        total_appearances: query::total_appearances(reference),
        network_mbps_total: network_mbps_per_cam.iter().sum(),
        network_mbps_per_cam,
        bytes_total: bytes_per_cam.iter().sum(),
        server_hz: if infer_secs_total > 0.0 { infer_count as f64 / infer_secs_total } else { 0.0 },
        camera_fps: stats::mean(&camera_fps),
        latency: LatencyBreakdown {
            camera: stats::mean(&lat.camera),
            network: stats::mean(&lat.network),
            server: stats::mean(&lat.server),
        },
        latency_p95: stats::percentile(&lat.total, 95.0),
        frames_reduced: out.frames_reduced,
        frames_total: n_frames * n_cams,
        mask_tiles: plan.masks.total_size(),
        mask_coverage: stats::mean(
            &(0..n_cams).map(|c| plan.masks.coverage(c)).collect::<Vec<_>>(),
        ),
        regions_per_cam: plan.groups.iter().map(|g| g.len()).collect(),
        consolidate_mode: opts.consolidate.name().to_string(),
        canvas_cams: if consolidation_active(opts.consolidate, &use_roi, &plan.groups, frame_px) {
            use_roi.iter().filter(|&&r| r).count()
        } else {
            0
        },
        offline_seconds: plan.seconds(),
        replan_count: replan_records.iter().map(|r| r.fired_components()).sum(),
        replan_warm_count: replan_records
            .iter()
            .flat_map(|r| r.components.iter())
            .filter(|c| c.fired && c.warm)
            .count(),
        replan_carried_components: replan_records
            .iter()
            .map(|r| r.carried_components())
            .sum(),
        replan_migrations: replan_records.iter().map(|r| r.migrated_components()).sum(),
        replan_reducto_rederived: replan_records.iter().map(|r| r.reducto_rederived).sum(),
        replan_mask_churn: stats::mean(
            &executed.iter().map(|r| r.mask_churn).collect::<Vec<_>>(),
        ),
        replan_seconds: replan_records.iter().map(|r| r.seconds).sum(),
        replan_done_at,
        replan_records,
        repair_records,
        arena_frame_allocs: out.arena.frame_allocs,
        arena_pixel_allocs: out.arena.pixel_allocs,
        arena_pixel_reuses: out.arena.pixel_reuses,
        arena_grid_allocs: out.arena.grid_allocs,
        arena_grid_reuses: out.arena.grid_reuses,
        arena_canvas_allocs: out.arena.canvas_allocs,
        arena_canvas_reuses: out.arena.canvas_reuses,
        planner_epochs_computed: pool.epochs_computed,
        planner_components_solved: pool.components_solved,
        planner_max_concurrent: pool.max_concurrent,
        planner_queue_wait_secs: pool.queue_wait_secs,
        canvas_count: canvas_tally.canvases(),
        canvas_fill_ratio: canvas_tally.mean_fill(frame_px),
        canvas_occupancy: canvas_tally.occupancy(),
    };
    Ok((report, reported))
}

/// Run a list of methods with the Baseline's results as the shared
/// accuracy reference (§5.2.1), on the default pipeline schedule.
pub fn run_ablation(
    scenario: &Scenario,
    sys: &SystemConfig,
    infer: &dyn Infer,
    methods: &[Method],
) -> Result<Vec<MethodReport>> {
    run_ablation_with(scenario, sys, infer, methods, &PipelineOptions::default())
}

/// [`run_ablation`] with an explicit schedule/cost model (e.g. pin
/// `Parallelism::Sequential` to measure uncontended service times on a
/// core-starved host).  Baseline is always run first.
pub fn run_ablation_with(
    scenario: &Scenario,
    sys: &SystemConfig,
    infer: &dyn Infer,
    methods: &[Method],
    opts: &PipelineOptions,
) -> Result<Vec<MethodReport>> {
    // §5.2.1: the reference is the Baseline method's detections fused with
    // the ReID ground truth.  We run Baseline first and collect its
    // per-frame reports as the reference, so Baseline scores 1.0 by
    // construction and every other method is scored against what the
    // full-data pipeline can actually detect.
    let (reference, baseline) = baseline_reference_with(scenario, sys, infer, opts)?;
    let mut out = Vec::new();
    for m in methods {
        if *m == Method::Baseline {
            out.push(baseline.clone());
        } else {
            out.push(run_method_with(scenario, sys, infer, m, Some(&reference), opts)?.0);
        }
    }
    Ok(out)
}

/// Run Baseline and return (its per-frame reported sets, its report scored
/// against itself — i.e. accuracy 1.0, zero misses, per §5.2.1).
pub fn baseline_reference(
    scenario: &Scenario,
    sys: &SystemConfig,
    infer: &dyn Infer,
) -> Result<(Vec<HashSet<u32>>, MethodReport)> {
    baseline_reference_with(scenario, sys, infer, &PipelineOptions::default())
}

/// [`baseline_reference`] with an explicit schedule/cost model.
pub fn baseline_reference_with(
    scenario: &Scenario,
    sys: &SystemConfig,
    infer: &dyn Infer,
    opts: &PipelineOptions,
) -> Result<(Vec<HashSet<u32>>, MethodReport)> {
    let (mut report, reported) =
        run_method_with(scenario, sys, infer, &Method::Baseline, None, opts)?;
    report.accuracy = 1.0;
    report.missed_per_frame = vec![0; reported.len()];
    report.total_appearances = query::total_appearances(&reported);
    Ok((reported, report))
}

// End-to-end coverage lives in rust/tests/online_pipeline.rs (method
// orderings, DES properties, the smoke run) and in
// rust/tests/pipeline_determinism.rs (byte-identical reports across
// schedules); the stage logic itself is unit-tested in crate::pipeline.
