//! Deprecated compatibility shim: the offline planner lives in
//! [`crate::offline`] — a staged subsystem (Profile → Filter → Associate
//! → Solve → Group) with parallel pair fitting, overlap sharding, a
//! pluggable set-cover solver and continuous re-profiling.
//!
//! These re-exports carry `#[deprecated]` so stale
//! `coordinator::offline::*` imports warn (pointing at the real module)
//! instead of silently aliasing it; they will be removed once nothing
//! external spells the old path.

#[deprecated(note = "use `crate::offline::build_plan`")]
pub use crate::offline::build_plan;
#[deprecated(note = "use `crate::offline::build_plan_from_stream`")]
pub use crate::offline::build_plan_from_stream;
#[deprecated(note = "use `crate::offline::build_plan_with`")]
pub use crate::offline::build_plan_with;
#[deprecated(note = "use `crate::offline::OfflineOptions`")]
pub use crate::offline::OfflineOptions;
#[deprecated(note = "use `crate::offline::OfflinePlan`")]
pub use crate::offline::OfflinePlan;
#[deprecated(note = "use `crate::offline::PlanReport`")]
pub use crate::offline::PlanReport;
#[deprecated(note = "use `crate::offline::ShardMode`")]
pub use crate::offline::ShardMode;
#[deprecated(note = "use `crate::offline::ShardReport`")]
pub use crate::offline::ShardReport;
#[deprecated(note = "use `crate::offline::SolverKind`")]
pub use crate::offline::SolverKind;
#[deprecated(note = "use `crate::offline::StageTiming`")]
pub use crate::offline::StageTiming;
