//! Offline phase (§4.1.1, modules ①–④): profile the synchronized clips,
//! clean the ReID stream, build region associations, optimize the RoI
//! masks and group their tiles — producing each camera's online plan.

use std::time::Instant;

use crate::association::table::AssociationTable;
use crate::association::tiles::Tiling;
use crate::config::{ScenarioConfig, SystemConfig};
use crate::coordinator::method::Method;
use crate::filters::ransac::RansacParams;
use crate::filters::svm::SvmParams;
use crate::filters::{FilterReport, TandemFilters};
use crate::reid::error_model::{ErrorModelParams, RawReid};
use crate::roi::masks::RoiMasks;
use crate::roi::setcover::{self, SolverParams};
use crate::sim::Scenario;
use crate::tilegroup;
use crate::util::geometry::IRect;

/// Per-fleet plan handed to the online phase.
#[derive(Debug, Clone)]
pub struct OfflinePlan {
    pub masks: RoiMasks,
    /// Codec regions per camera (grouped rectangles, or per-tile rects for
    /// No-Merging, or the full frame for Baseline).
    pub groups: Vec<Vec<IRect>>,
    /// Active detector blocks per camera (for the RoI HLO variant).
    pub blocks: Vec<Vec<i32>>,
    /// Filter diagnostics (None when filters were off).
    pub filter_report: Option<FilterReport>,
    /// Association table size (diagnostics).
    pub n_constraints: usize,
    /// Wall-clock seconds the offline phase took.
    pub seconds: f64,
}

/// Run the offline phase for a method.
///
/// * Baseline / Reducto: full-frame masks, one full-frame region.
/// * No-Filters: raw ReID straight into the optimizer (② off).
/// * No-Merging: optimized masks but per-tile regions (tile grouping off).
/// * No-RoIInf / CrossRoI / CrossRoI-Reducto: the full pipeline.
pub fn build_plan(
    scenario: &Scenario,
    cfg: &ScenarioConfig,
    sys: &SystemConfig,
    method: &Method,
) -> OfflinePlan {
    let start = Instant::now();
    let tiling = Tiling::new(
        scenario.cameras.len(),
        crate::sim::FRAME_W,
        crate::sim::FRAME_H,
        cfg.tile_px,
    );

    if !method.uses_roi_masks() {
        let masks = RoiMasks::full(&tiling);
        let n_cams = scenario.cameras.len();
        let full_rect = vec![IRect::new(0, 0, crate::sim::FRAME_W, crate::sim::FRAME_H)];
        let blocks: Vec<Vec<i32>> =
            (0..n_cams).map(|c| masks.active_blocks(c, 32, crate::sim::FRAME_W)).collect();
        return OfflinePlan {
            groups: vec![full_rect; n_cams],
            blocks,
            masks,
            filter_report: None,
            n_constraints: 0,
            seconds: start.elapsed().as_secs_f64(),
        };
    }

    // ① offline ReID over the profile window
    let raw = RawReid::generate(scenario, scenario.profile_range(), &ErrorModelParams::default());

    // ② tandem statistical filters (skipped by No-Filters)
    let (stream, filter_report) = if method.uses_filters() {
        let filters = TandemFilters {
            ransac: RansacParams { theta: sys.ransac_theta, ..Default::default() },
            svm: SvmParams { gamma: sys.svm_gamma, ..Default::default() },
            ..Default::default()
        };
        let (s, r) = filters.apply(&raw);
        (s, Some(r))
    } else {
        (raw, None)
    };

    // ③ region association lookup table
    let table = AssociationTable::build(&stream, &tiling);

    // ④ RoI mask optimization
    let solution = setcover::solve(&table, &SolverParams::default());
    let masks = RoiMasks::from_solution(&tiling, &solution.tiles);

    // ⑤-prep: tile grouping (skipped by No-Merging)
    let groups: Vec<Vec<IRect>> = if method.uses_merging() {
        tilegroup::group_all(&masks)
    } else {
        (0..scenario.cameras.len()).map(|c| masks.tile_rects(c)).collect()
    };
    let blocks: Vec<Vec<i32>> = (0..scenario.cameras.len())
        .map(|c| masks.active_blocks(c, 32, crate::sim::FRAME_W))
        .collect();

    OfflinePlan {
        masks,
        groups,
        blocks,
        filter_report,
        n_constraints: table.n_constraints(),
        seconds: start.elapsed().as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn setup() -> (Scenario, Config) {
        let cfg = Config::test_small();
        (Scenario::build(&cfg.scenario), cfg)
    }

    #[test]
    fn baseline_plan_is_full_frame() {
        let (sc, cfg) = setup();
        let plan = build_plan(&sc, &cfg.scenario, &cfg.system, &Method::Baseline);
        assert_eq!(plan.groups[0], vec![IRect::new(0, 0, 320, 192)]);
        assert_eq!(plan.blocks[0].len(), 60);
        assert!((plan.masks.coverage(0) - 1.0).abs() < 1e-12);
        assert!(plan.filter_report.is_none());
    }

    #[test]
    fn crossroi_plan_reduces_tiles() {
        let (sc, cfg) = setup();
        let plan = build_plan(&sc, &cfg.scenario, &cfg.system, &Method::CrossRoi);
        let total: usize = (0..5).map(|c| plan.masks.camera_size(c)).sum();
        assert!(total > 0, "empty masks");
        assert!(
            total < 5 * 240,
            "CrossRoI masks did not shrink below full frames: {total}"
        );
        assert!(plan.filter_report.is_some());
        assert!(plan.n_constraints > 0);
        // grouped regions are fewer than tiles
        for cam in 0..5 {
            assert!(plan.groups[cam].len() <= plan.masks.camera_size(cam));
        }
    }

    #[test]
    fn no_merging_uses_per_tile_regions() {
        let (sc, cfg) = setup();
        let merged = build_plan(&sc, &cfg.scenario, &cfg.system, &Method::CrossRoi);
        let unmerged = build_plan(&sc, &cfg.scenario, &cfg.system, &Method::NoMerging);
        // identical masks (same seed/profile), different region granularity
        assert_eq!(merged.masks.total_size(), unmerged.masks.total_size());
        for cam in 0..5 {
            assert_eq!(unmerged.groups[cam].len(), unmerged.masks.camera_size(cam));
            assert!(merged.groups[cam].len() <= unmerged.groups[cam].len());
        }
    }

    #[test]
    fn no_filters_masks_are_larger() {
        let (sc, cfg) = setup();
        let with = build_plan(&sc, &cfg.scenario, &cfg.system, &Method::CrossRoi);
        let without = build_plan(&sc, &cfg.scenario, &cfg.system, &Method::NoFilters);
        // false negatives force both copies of every broken pair into the
        // masks: the unfiltered plan must be at least as large
        assert!(
            without.masks.total_size() >= with.masks.total_size(),
            "no-filters {} < crossroi {}",
            without.masks.total_size(),
            with.masks.total_size()
        );
    }

    #[test]
    fn blocks_cover_mask_tiles() {
        let (sc, cfg) = setup();
        let plan = build_plan(&sc, &cfg.scenario, &cfg.system, &Method::CrossRoi);
        for cam in 0..5 {
            for &(tx, ty) in plan.masks.tiles[cam].iter() {
                let bid = ((ty / 2) * 10 + tx / 2) as i32;
                assert!(
                    plan.blocks[cam].contains(&bid),
                    "cam {cam} tile ({tx},{ty}) not covered by block {bid}"
                );
            }
        }
    }
}
