//! Compatibility shim: the offline planner lives in [`crate::offline`] —
//! a staged subsystem (Profile → Filter → Associate → Solve → Group) with
//! parallel pair fitting and a pluggable set-cover solver.  Re-exported
//! here so the coordinator's historical public surface
//! (`coordinator::build_plan`) keeps working.

pub use crate::offline::{
    build_plan, build_plan_from_stream, build_plan_with, OfflineOptions, OfflinePlan,
    PlanReport, ShardMode, ShardReport, SolverKind, StageTiming,
};
