//! Metrics reported per method — one row of Fig. 8 / Table 4.

/// End-to-end latency decomposition (Fig. 8f's stacked bars).
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencyBreakdown {
    /// Capture-to-encode-done (includes segment queueing — the dominant
    /// term, §5.3.3).
    pub camera: f64,
    /// Encode-done to server arrival (link queueing + tx + propagation).
    pub network: f64,
    /// Arrival to inference completion (server queue + inference).
    pub server: f64,
}

impl LatencyBreakdown {
    pub fn total(&self) -> f64 {
        self.camera + self.network + self.server
    }
}

/// Everything a method run produces.
#[derive(Debug, Clone, Default)]
pub struct MethodReport {
    pub method: String,
    // --- §5.1.2 metric 1: accuracy ---
    pub accuracy: f64,
    /// Missed unique vehicles per evaluated frame (Fig. 8b).
    pub missed_per_frame: Vec<usize>,
    /// Total vehicle appearances in the reference window.
    pub total_appearances: usize,
    // --- metric 2: network overhead ---
    /// Average Mbps per camera over the eval window (Fig. 8c bars).
    pub network_mbps_per_cam: Vec<f64>,
    pub network_mbps_total: f64,
    pub bytes_total: u64,
    // --- metric 3: throughput ---
    /// Server inference throughput in Hz (frames per second of inference
    /// busy time, measured on the real executables).
    pub server_hz: f64,
    /// Camera-side encode throughput in fps (mean across cameras).
    pub camera_fps: f64,
    // --- metric 4: end-to-end latency ---
    pub latency: LatencyBreakdown,
    pub latency_p95: f64,
    // --- diagnostics ---
    /// Frames discarded by the frame filter (Table 4 "Frames Reduced").
    pub frames_reduced: usize,
    pub frames_total: usize,
    /// |M| — mask tiles kept (0 for full-frame methods means "all").
    pub mask_tiles: usize,
    /// Mean mask coverage fraction across cameras.
    pub mask_coverage: f64,
    /// Regions per camera after grouping (diagnostic for §4.3).
    pub regions_per_cam: Vec<usize>,
    /// Wall-clock cost of running the method's offline phase (seconds).
    pub offline_seconds: f64,
}

impl MethodReport {
    /// One formatted row for the bench tables.
    pub fn row(&self) -> String {
        format!(
            "{:<18} acc={:.3} net={:6.2} Mbps  srv={:7.1} Hz  cam={:6.1} fps  e2e={:6.3} s (cam {:.3} / net {:.3} / srv {:.3})",
            self.method,
            self.accuracy,
            self.network_mbps_total,
            self.server_hz,
            self.camera_fps,
            self.latency.total(),
            self.latency.camera,
            self.latency.network,
            self.latency.server,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_total() {
        let l = LatencyBreakdown { camera: 1.0, network: 0.25, server: 0.5 };
        assert!((l.total() - 1.75).abs() < 1e-12);
    }

    #[test]
    fn row_formats() {
        let mut r = MethodReport::default();
        r.method = "CrossRoI".to_string();
        r.accuracy = 0.999;
        let row = r.row();
        assert!(row.contains("CrossRoI"));
        assert!(row.contains("acc=0.999"));
    }
}
