//! Metrics reported per method — one row of Fig. 8 / Table 4.

use crate::offline::replan::{RepairRecord, ReplanRecord};
use crate::util::json::Json;

/// End-to-end latency decomposition (Fig. 8f's stacked bars).
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencyBreakdown {
    /// Capture-to-encode-done (includes segment queueing — the dominant
    /// term, §5.3.3).
    pub camera: f64,
    /// Encode-done to server arrival (link queueing + tx + propagation).
    pub network: f64,
    /// Arrival to inference completion (server queue + inference).
    pub server: f64,
}

impl LatencyBreakdown {
    pub fn total(&self) -> f64 {
        self.camera + self.network + self.server
    }
}

/// Everything a method run produces.
#[derive(Debug, Clone, Default)]
pub struct MethodReport {
    pub method: String,
    // --- §5.1.2 metric 1: accuracy ---
    pub accuracy: f64,
    /// Missed unique vehicles per evaluated frame (Fig. 8b).
    pub missed_per_frame: Vec<usize>,
    /// Total vehicle appearances in the reference window.
    pub total_appearances: usize,
    // --- metric 2: network overhead ---
    /// Average Mbps per camera over the eval window (Fig. 8c bars).
    pub network_mbps_per_cam: Vec<f64>,
    pub network_mbps_total: f64,
    pub bytes_total: u64,
    // --- metric 3: throughput ---
    /// Server inference throughput in Hz (frames per second of inference
    /// busy time, measured on the real executables).
    pub server_hz: f64,
    /// Camera-side encode throughput in fps (mean across cameras).
    pub camera_fps: f64,
    // --- metric 4: end-to-end latency ---
    pub latency: LatencyBreakdown,
    pub latency_p95: f64,
    // --- diagnostics ---
    /// Frames discarded by the frame filter (Table 4 "Frames Reduced").
    pub frames_reduced: usize,
    pub frames_total: usize,
    /// |M| — mask tiles kept (0 for full-frame methods means "all").
    pub mask_tiles: usize,
    /// Mean mask coverage fraction across cameras.
    pub mask_coverage: f64,
    /// Regions per camera after grouping (diagnostic for §4.3).
    pub regions_per_cam: Vec<usize>,
    /// Cross-camera consolidation policy in effect (`--consolidate`):
    /// "auto", "on" or "off" (DESIGN.md §13).
    pub consolidate_mode: String,
    /// Cameras routed through packed canvases under the initial plan — a
    /// pure function of plan and policy, so it is serialized.
    pub canvas_cams: usize,
    /// Wall-clock cost of running the method's offline phase (seconds).
    pub offline_seconds: f64,
    // --- continuous re-profiling (DESIGN.md §7–§8; zero/empty when the
    // policy is `Never`) ---
    /// Component re-solves executed over the run: only components whose
    /// window actually fired the policy are counted (under
    /// `--replan-scope fleet` the whole fleet is one component, so this
    /// is the number of fired epochs).  Mere drift checks — and carried
    /// components — are not counted.
    pub replan_count: usize,
    /// Executed component re-solves served by the warm-started solver
    /// (vs fresh from-scratch re-solves).
    pub replan_warm_count: usize,
    /// Components checked at an epoch boundary but carried forward
    /// untouched (their cameras kept masks, encoder state and — for
    /// frame-filter methods — thresholds).
    pub replan_carried_components: usize,
    /// Components that changed camera membership mid-run (the component
    /// diff fired both the donor and the recipient fresh).
    pub replan_migrations: usize,
    /// Cameras whose Reducto frame-filter threshold was re-derived from
    /// the sliding window because a re-plan changed their regions.
    pub replan_reducto_rederived: usize,
    /// Mean mask churn (Jaccard distance between consecutive global tile
    /// sets) across executed re-plans.
    pub replan_mask_churn: f64,
    /// Wall seconds spent re-profiling: drift checks + executed re-plans
    /// (like `offline_seconds`, inherently wall-clock).
    pub replan_seconds: f64,
    /// DES-clock completion time of each executed re-plan (epoch-boundary
    /// trigger + measured planning seconds, timestamped by the transport
    /// replay).
    pub replan_done_at: Vec<f64>,
    /// Full per-epoch re-plan records, including each component's
    /// disposition (fired/carried/migrated, solver, drift) — serialized
    /// into the JSON dump after [`MethodReport::zero_wall_clock`] zeroes
    /// each record's wall-clock `seconds`.
    pub replan_records: Vec<ReplanRecord>,
    /// One record per fault obligation (dropout repair or rejoin) the
    /// planner executed: detection latency on the segment-deadline
    /// liveness clock, repair latency in epochs, and the orphaned /
    /// re-covered / uncovered tile accounting (DESIGN.md §12).  Each
    /// record's wall-clock `seconds` is zeroed by
    /// [`MethodReport::zero_wall_clock`]; everything else is a pure
    /// function of the fault schedule and the segment grid.
    pub repair_records: Vec<RepairRecord>,
    // --- buffer-arena diagnostics (DESIGN.md §9; counters depend on
    // thread interleaving, so they are NOT serialized in `to_json` —
    // the byte-compared determinism contract excludes them) ---
    /// Fresh frame buffers allocated by camera workers.
    pub arena_frame_allocs: usize,
    /// Fresh detector-input pixel buffers allocated.
    pub arena_pixel_allocs: usize,
    /// Detector-input pixel buffers recycled through the arena.
    pub arena_pixel_reuses: usize,
    /// Fresh inference-grid buffers allocated on the server side.
    pub arena_grid_allocs: usize,
    /// Inference-grid buffers recycled through the arena.
    pub arena_grid_reuses: usize,
    /// Fresh consolidation-canvas buffers allocated on the server side.
    pub arena_canvas_allocs: usize,
    /// Consolidation-canvas buffers recycled through the arena.
    pub arena_canvas_reuses: usize,
    // --- planner-pool diagnostics (DESIGN.md §10; same contract as the
    // arena counters: schedule-dependent, NOT serialized in `to_json`) ---
    /// Epoch boundaries whose compute phase ran (carried or fired).
    pub planner_epochs_computed: usize,
    /// Component solves dispatched to the planner pool.
    pub planner_components_solved: usize,
    /// High-water mark of component solves running simultaneously.
    pub planner_max_concurrent: usize,
    /// Total seconds component solves waited for a pool worker.
    pub planner_queue_wait_secs: f64,
    // --- canvas-consolidation diagnostics (DESIGN.md §13; packing runs
    // per merged batch, so these depend on batch composition — same
    // contract as the arena counters: NOT serialized in `to_json`) ---
    /// Dense canvases packed and inferred over the run.
    pub canvas_count: usize,
    /// Mean fraction of canvas pixels carrying gathered tile groups.
    pub canvas_fill_ratio: f64,
    /// Mean camera-jobs folded into each canvas (batch occupancy).
    pub canvas_occupancy: f64,
}

impl MethodReport {
    /// One formatted row for the bench tables.
    pub fn row(&self) -> String {
        format!(
            "{:<18} acc={:.3} net={:6.2} Mbps  srv={:7.1} Hz  cam={:6.1} fps  e2e={:6.3} s (cam {:.3} / net {:.3} / srv {:.3})",
            self.method,
            self.accuracy,
            self.network_mbps_total,
            self.server_hz,
            self.camera_fps,
            self.latency.total(),
            self.latency.camera,
            self.latency.network,
            self.latency.server,
        )
    }

    /// Full report as a JSON document (experiment dumps; the determinism
    /// test compares these byte-for-byte across pipeline schedules).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("method", Json::Str(self.method.clone())),
            ("accuracy", Json::Num(self.accuracy)),
            (
                "missed_per_frame",
                Json::Arr(self.missed_per_frame.iter().map(|&m| Json::Num(m as f64)).collect()),
            ),
            ("total_appearances", Json::Num(self.total_appearances as f64)),
            ("network_mbps_per_cam", Json::arr_f64(&self.network_mbps_per_cam)),
            ("network_mbps_total", Json::Num(self.network_mbps_total)),
            ("bytes_total", Json::Num(self.bytes_total as f64)),
            ("server_hz", Json::Num(self.server_hz)),
            ("camera_fps", Json::Num(self.camera_fps)),
            ("latency_camera", Json::Num(self.latency.camera)),
            ("latency_network", Json::Num(self.latency.network)),
            ("latency_server", Json::Num(self.latency.server)),
            ("latency_p95", Json::Num(self.latency_p95)),
            ("frames_reduced", Json::Num(self.frames_reduced as f64)),
            ("frames_total", Json::Num(self.frames_total as f64)),
            ("mask_tiles", Json::Num(self.mask_tiles as f64)),
            ("mask_coverage", Json::Num(self.mask_coverage)),
            (
                "regions_per_cam",
                Json::Arr(self.regions_per_cam.iter().map(|&r| Json::Num(r as f64)).collect()),
            ),
            ("consolidate_mode", Json::Str(self.consolidate_mode.clone())),
            ("canvas_cams", Json::Num(self.canvas_cams as f64)),
            ("offline_seconds", Json::Num(self.offline_seconds)),
            ("replan_count", Json::Num(self.replan_count as f64)),
            ("replan_warm_count", Json::Num(self.replan_warm_count as f64)),
            (
                "replan_carried_components",
                Json::Num(self.replan_carried_components as f64),
            ),
            ("replan_migrations", Json::Num(self.replan_migrations as f64)),
            (
                "replan_reducto_rederived",
                Json::Num(self.replan_reducto_rederived as f64),
            ),
            ("replan_mask_churn", Json::Num(self.replan_mask_churn)),
            ("replan_seconds", Json::Num(self.replan_seconds)),
            ("replan_done_at", Json::arr_f64(&self.replan_done_at)),
            (
                "replan_records",
                Json::Arr(self.replan_records.iter().map(ReplanRecord::to_json).collect()),
            ),
            (
                "repair_records",
                Json::Arr(self.repair_records.iter().map(RepairRecord::to_json).collect()),
            ),
        ])
    }

    /// Zero every inherently wall-clock field in place, preserving shape
    /// (lengths, counts) — the determinism tests byte-compare the JSON of
    /// runs across pipeline schedules, and only these fields (plus the
    /// unserialized arena counters) may legitimately differ.
    pub fn zero_wall_clock(&mut self) {
        self.offline_seconds = 0.0;
        self.replan_seconds = 0.0;
        self.replan_done_at = vec![0.0; self.replan_done_at.len()];
        for rec in &mut self.replan_records {
            rec.seconds = 0.0;
            for comp in &mut rec.components {
                comp.seconds = 0.0;
                comp.queue_wait = 0.0;
            }
        }
        for rep in &mut self.repair_records {
            rep.seconds = 0.0;
        }
        self.arena_frame_allocs = 0;
        self.arena_pixel_allocs = 0;
        self.arena_pixel_reuses = 0;
        self.arena_grid_allocs = 0;
        self.arena_grid_reuses = 0;
        self.arena_canvas_allocs = 0;
        self.arena_canvas_reuses = 0;
        self.planner_epochs_computed = 0;
        self.planner_components_solved = 0;
        self.planner_max_concurrent = 0;
        self.planner_queue_wait_secs = 0.0;
        self.canvas_count = 0;
        self.canvas_fill_ratio = 0.0;
        self.canvas_occupancy = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_total() {
        let l = LatencyBreakdown { camera: 1.0, network: 0.25, server: 0.5 };
        assert!((l.total() - 1.75).abs() < 1e-12);
    }

    #[test]
    fn row_formats() {
        let mut r = MethodReport::default();
        r.method = "CrossRoI".to_string();
        r.accuracy = 0.999;
        let row = r.row();
        assert!(row.contains("CrossRoI"));
        assert!(row.contains("acc=0.999"));
    }

    #[test]
    fn json_roundtrips_and_is_stable() {
        let mut r = MethodReport::default();
        r.method = "CrossRoI".to_string();
        r.accuracy = 0.987;
        r.network_mbps_per_cam = vec![0.5, 0.25];
        r.missed_per_frame = vec![0, 1, 2];
        let text = r.to_json().to_string_pretty(2);
        let parsed = crate::util::json::parse(&text).unwrap();
        assert_eq!(parsed.get("method").unwrap().as_str(), Some("CrossRoI"));
        assert_eq!(parsed.get("accuracy").unwrap().as_f64(), Some(0.987));
        assert_eq!(parsed.get("missed_per_frame").unwrap().as_arr().unwrap().len(), 3);
        // identical reports serialize identically (byte-wise)
        assert_eq!(text, r.clone().to_json().to_string_pretty(2));
    }

    fn sample_record() -> ReplanRecord {
        use crate::offline::replan::ComponentRecord;
        ReplanRecord {
            epoch: 2,
            start_seg: 4,
            trigger_time: 12.5,
            seconds: 0.031,
            replanned: true,
            warm: true,
            constraint_drift: 0.25,
            mask_churn: 0.1,
            solver: "greedy",
            n_constraints: 40,
            mask_tiles: 77,
            scope: "component",
            components: vec![
                ComponentRecord {
                    cameras: vec![0, 2],
                    drift: 0.3,
                    fired: true,
                    warm: true,
                    migrated: false,
                    spill_groups: 2,
                    n_constraints: 25,
                    solver: "greedy",
                    seconds: 0.012,
                    queue_wait: 0.002,
                },
                ComponentRecord {
                    cameras: vec![1],
                    drift: 0.0,
                    fired: false,
                    warm: false,
                    migrated: false,
                    spill_groups: 0,
                    n_constraints: 15,
                    solver: "carried",
                    seconds: 0.0,
                    queue_wait: 0.0,
                },
            ],
            reducto_rederived: 1,
        }
    }

    fn sample_repair() -> RepairRecord {
        RepairRecord {
            cam: 1,
            kind: "dropout",
            fail_secs: 4.5,
            detect_secs: 6.0,
            detect_latency: 1.5,
            epoch: 2,
            repair_latency_epochs: 1,
            orphaned_tiles: 12,
            recovered_tiles: 9,
            uncovered_constraints: 2,
            seconds: 0.02,
        }
    }

    #[test]
    fn repair_records_round_trip_through_json() {
        let mut r = MethodReport::default();
        r.method = "CrossRoI".to_string();
        r.repair_records = vec![sample_repair()];
        let text = r.to_json().to_string_pretty(2);
        let parsed = crate::util::json::parse(&text).unwrap();
        let records = parsed.get("repair_records").unwrap().as_arr().unwrap();
        assert_eq!(records.len(), 1);
        let rec = &records[0];
        assert_eq!(rec.get("cam").unwrap().as_f64(), Some(1.0));
        assert_eq!(rec.get("kind").unwrap().as_str(), Some("dropout"));
        assert_eq!(rec.get("detect_latency").unwrap().as_f64(), Some(1.5));
        assert_eq!(rec.get("repair_latency_epochs").unwrap().as_f64(), Some(1.0));
        assert_eq!(rec.get("orphaned_tiles").unwrap().as_f64(), Some(12.0));
        assert_eq!(rec.get("recovered_tiles").unwrap().as_f64(), Some(9.0));
        assert_eq!(rec.get("uncovered_constraints").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn replan_records_round_trip_through_json() {
        let mut r = MethodReport::default();
        r.method = "CrossRoI".to_string();
        r.replan_records = vec![sample_record()];
        let text = r.to_json().to_string_pretty(2);
        let parsed = crate::util::json::parse(&text).unwrap();
        let records = parsed.get("replan_records").unwrap().as_arr().unwrap();
        assert_eq!(records.len(), 1);
        let rec = &records[0];
        assert_eq!(rec.get("epoch").unwrap().as_f64(), Some(2.0));
        assert_eq!(rec.get("trigger_time").unwrap().as_f64(), Some(12.5));
        assert_eq!(rec.get("solver").unwrap().as_str(), Some("greedy"));
        assert_eq!(rec.get("scope").unwrap().as_str(), Some("component"));
        assert_eq!(rec.get("replanned").unwrap(), &Json::Bool(true));
        let comps = rec.get("components").unwrap().as_arr().unwrap();
        assert_eq!(comps.len(), 2);
        assert_eq!(comps[0].get("cameras").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(comps[0].get("fired").unwrap(), &Json::Bool(true));
        assert_eq!(comps[1].get("fired").unwrap(), &Json::Bool(false));
        assert_eq!(comps[1].get("solver").unwrap().as_str(), Some("carried"));
        assert_eq!(comps[0].get("spill_groups").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn zero_wall_clock_keeps_shape_and_deterministic_fields() {
        let mut r = MethodReport::default();
        r.offline_seconds = 3.5;
        r.replan_seconds = 1.25;
        r.replan_done_at = vec![10.0, 12.0];
        r.replan_records = vec![sample_record()];
        r.repair_records = vec![sample_repair()];
        r.arena_frame_allocs = 7;
        r.arena_pixel_allocs = 9;
        r.arena_pixel_reuses = 40;
        r.arena_grid_allocs = 3;
        r.arena_grid_reuses = 21;
        r.arena_canvas_allocs = 2;
        r.arena_canvas_reuses = 11;
        r.planner_epochs_computed = 4;
        r.planner_components_solved = 6;
        r.planner_max_concurrent = 3;
        r.planner_queue_wait_secs = 0.5;
        r.canvas_count = 8;
        r.canvas_fill_ratio = 0.6;
        r.canvas_occupancy = 2.5;
        r.consolidate_mode = "auto".to_string();
        r.canvas_cams = 4;
        r.zero_wall_clock();
        assert_eq!(r.offline_seconds, 0.0);
        assert_eq!(r.replan_seconds, 0.0);
        assert_eq!(r.replan_done_at, vec![0.0, 0.0], "shape must be preserved");
        assert_eq!(r.replan_records[0].seconds, 0.0);
        // per-component wall-clock (solve time, pool queue wait) zeroes too
        assert!(r.replan_records[0]
            .components
            .iter()
            .all(|c| c.seconds == 0.0 && c.queue_wait == 0.0));
        // virtual-clock and outcome fields survive
        assert_eq!(r.replan_records[0].trigger_time, 12.5);
        assert!(r.replan_records[0].replanned);
        assert_eq!(r.repair_records[0].seconds, 0.0);
        assert_eq!(r.repair_records[0].detect_latency, 1.5, "detection latency is DES-clock");
        assert_eq!(r.repair_records[0].repair_latency_epochs, 1);
        assert_eq!(r.arena_pixel_reuses, 0);
        assert_eq!(r.arena_grid_reuses, 0);
        assert_eq!(r.arena_canvas_allocs, 0);
        assert_eq!(r.arena_canvas_reuses, 0);
        assert_eq!(r.planner_components_solved, 0);
        assert_eq!(r.planner_queue_wait_secs, 0.0);
        assert_eq!(r.canvas_count, 0);
        assert_eq!(r.canvas_fill_ratio, 0.0);
        assert_eq!(r.canvas_occupancy, 0.0);
        // routing policy is plan-derived, not wall-clock: it survives
        assert_eq!(r.consolidate_mode, "auto");
        assert_eq!(r.canvas_cams, 4);
    }
}
