//! `cargo xtask analyze` — the repo's invariant lint driver (DESIGN.md
//! §11).
//!
//! Three repo-specific passes over `rust/src`, each guarding a
//! determinism or soundness contract the ordinary compiler gates cannot
//! see:
//!
//! 1. **order-determinism** — iterating a `HashMap`/`HashSet` yields an
//!    arbitrary order, which must never reach serialized or merged
//!    output.  In the modules that feed such output (`roi/`, `offline/`,
//!    `query/`, `coordinator/`, `pipeline/`), every hash-collection
//!    iteration site must either be followed by a sort within the next
//!    few lines or carry a `// lint: order-insensitive` justification.
//! 2. **wall-clock hygiene** — `SystemTime` is banned outright, and
//!    `Instant::now` in the watched modules must be annotated
//!    `// lint: wall-clock` (site) or `// lint: wall-clock-file` (file
//!    header) declaring its readings reach only fields zeroed by
//!    `MethodReport::zero_wall_clock` before byte-comparison.  The pass
//!    also checks `zero_wall_clock`'s body against the manifest of
//!    wall-clock field tokens.
//! 3. **unsafe discipline** — `unsafe` may appear only in the
//!    allowlisted codec/runtime files, every occurrence needs a
//!    `// SAFETY:` (or `# Safety` doc section) within the eight lines
//!    above, and `rust/src/lib.rs` must carry `#[forbid(unsafe_code)]`
//!    on every module except `codec`/`runtime`, which get
//!    `#[deny(unsafe_op_in_unsafe_fn)]`.
//!
//! The scanners are line-based token matchers (no rustc plumbing, no
//! dependencies): deliberately conservative, so a false positive is
//! silenced with an annotation that doubles as reviewer documentation.
//! Findings go to stdout and `target/xtask-findings.txt` (the CI
//! artifact); any finding exits nonzero.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt::Write as _;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Modules whose output is serialized or merged across threads.
const WATCHED_DIRS: &[&str] = &["roi", "offline", "query", "coordinator", "pipeline"];

/// The only files allowed to contain `unsafe` (SIMD kernels + PJRT FFI).
const UNSAFE_ALLOWLIST: &[&str] = &[
    "codec/kernels.rs",
    "codec/dct.rs",
    "codec/motion.rs",
    "codec/entropy.rs",
    "runtime/client.rs",
];

/// Hash-collection iteration entry points (pass 1).
const ITER_METHODS: &[&str] = &[
    ".iter()",
    ".into_iter()",
    ".keys()",
    ".into_keys()",
    ".values()",
    ".into_values()",
    ".drain(",
];

/// Tokens `MethodReport::zero_wall_clock` must touch — one per
/// wall-clock field family (rust/tests/report_shape.rs holds the
/// compile-time side of this contract).
const ZERO_WALL_CLOCK_MANIFEST: &[&str] = &[
    "offline_seconds",
    "replan_seconds",
    "replan_done_at",
    "rec.seconds",
    "comp.seconds",
    "comp.queue_wait",
    "rep.seconds",
    "arena_frame_allocs",
    "arena_pixel_allocs",
    "arena_pixel_reuses",
    "arena_grid_allocs",
    "arena_grid_reuses",
    "arena_canvas_allocs",
    "arena_canvas_reuses",
    "planner_epochs_computed",
    "planner_components_solved",
    "planner_max_concurrent",
    "planner_queue_wait_secs",
    "canvas_count",
    "canvas_fill_ratio",
    "canvas_occupancy",
];

/// Lines of sort-following-iteration tolerated by pass 1 (the common
/// `collect → sort_unstable` idiom).
const SORT_WINDOW: usize = 6;

/// Lines of `// SAFETY:` lookback tolerated by pass 3.
const SAFETY_WINDOW: usize = 8;

struct Finding {
    pass: &'static str,
    file: String,
    line: usize,
    message: String,
}

/// One scanned source file: raw lines for annotation/comment checks,
/// comment-stripped lines for token matching, and the index where the
/// trailing `#[cfg(test)]` section starts (tests are exempt from passes
/// 1–2 — they do not feed serialized output).
struct SourceFile {
    rel: String,
    raw: Vec<String>,
    code: Vec<String>,
    test_start: usize,
}

impl SourceFile {
    fn watched(&self) -> bool {
        WATCHED_DIRS.iter().any(|d| self.rel.starts_with(&format!("{d}/")))
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("analyze") | None => analyze(),
        Some(other) => {
            eprintln!("unknown xtask command {other:?} (commands: analyze)");
            ExitCode::FAILURE
        }
    }
}

fn analyze() -> ExitCode {
    // xtask/ lives directly under the repo root
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask sits under the repo root")
        .to_path_buf();
    let src = root.join("rust").join("src");
    let files = load_tree(&src);
    eprintln!("xtask analyze: scanning {} files under rust/src", files.len());

    let mut findings = Vec::new();
    let (global_idents, per_file_idents) = hash_idents(&files);
    findings.extend(pass_order_determinism(&files, &global_idents, &per_file_idents));
    findings.extend(pass_wall_clock(&files));
    findings.extend(pass_unsafe_discipline(&files));

    let mut report = String::new();
    for f in &findings {
        let _ = writeln!(report, "[{}] rust/src/{}:{}: {}", f.pass, f.file, f.line, f.message);
    }
    let _ = fs::create_dir_all(root.join("target"));
    let _ = fs::write(root.join("target").join("xtask-findings.txt"), &report);

    if findings.is_empty() {
        eprintln!("xtask analyze: clean (order-determinism, wall-clock, unsafe)");
        ExitCode::SUCCESS
    } else {
        print!("{report}");
        eprintln!("xtask analyze: {} finding(s)", findings.len());
        ExitCode::FAILURE
    }
}

// ---------------------------------------------------------------------
// tree loading + comment stripping
// ---------------------------------------------------------------------

fn load_tree(src: &Path) -> Vec<SourceFile> {
    let mut paths = Vec::new();
    collect_rs(src, &mut paths);
    paths.sort();
    paths
        .into_iter()
        .map(|p| {
            let rel = p
                .strip_prefix(src)
                .expect("collected under src")
                .to_string_lossy()
                .replace('\\', "/");
            let text = fs::read_to_string(&p)
                .unwrap_or_else(|e| panic!("read {}: {e}", p.display()));
            let raw: Vec<String> = text.lines().map(str::to_string).collect();
            let mut in_block = false;
            let code: Vec<String> =
                raw.iter().map(|l| strip_comments(l, &mut in_block)).collect();
            // the test *module* (`#[cfg(test)] mod …`) ends the scanned
            // region; a bare `#[cfg(test)]` on a free fn (test hooks
            // interleaved with real code, e.g. roi/setcover.rs) does not
            let test_start = raw
                .iter()
                .enumerate()
                .position(|(i, l)| {
                    l.trim() == "#[cfg(test)]"
                        && raw.get(i + 1).is_some_and(|n| n.trim_start().starts_with("mod "))
                })
                .unwrap_or(raw.len());
            SourceFile { rel, raw, code, test_start }
        })
        .collect()
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let entries = fs::read_dir(dir).unwrap_or_else(|e| panic!("read_dir {}: {e}", dir.display()));
    for entry in entries {
        let path = entry.expect("dir entry").path();
        if path.is_dir() {
            collect_rs(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// Strip `//` line comments and `/* */` block comments, preserving
/// string literals (a `//` inside a string is not a comment) and char
/// literals (a lifetime's `'` does not open one).
fn strip_comments(line: &str, in_block: &mut bool) -> String {
    let b = line.as_bytes();
    let mut out = String::with_capacity(line.len());
    let mut i = 0;
    let mut in_str = false;
    while i < b.len() {
        if *in_block {
            if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                *in_block = false;
                i += 2;
            } else {
                i += 1;
            }
            continue;
        }
        let c = b[i];
        if in_str {
            out.push(c as char);
            if c == b'\\' && i + 1 < b.len() {
                out.push(b[i + 1] as char);
                i += 2;
                continue;
            }
            if c == b'"' {
                in_str = false;
            }
            i += 1;
            continue;
        }
        match c {
            b'"' => {
                in_str = true;
                out.push('"');
                i += 1;
            }
            b'/' if i + 1 < b.len() && b[i + 1] == b'/' => break,
            b'/' if i + 1 < b.len() && b[i + 1] == b'*' => {
                *in_block = true;
                i += 2;
            }
            b'\'' => {
                // a closing quote 2–3 bytes on means a char literal
                // ('x' or '\n'); otherwise it is a lifetime tick
                let close = [i + 2, i + 3].into_iter().find(|&j| j < b.len() && b[j] == b'\'');
                match close {
                    Some(j) => {
                        for &k in b.iter().take(j + 1).skip(i) {
                            out.push(k as char);
                        }
                        i = j + 1;
                    }
                    None => {
                        out.push('\'');
                        i += 1;
                    }
                }
            }
            _ => {
                out.push(c as char);
                i += 1;
            }
        }
    }
    out
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// `word` present in `hay` with non-identifier bytes (or edges) on both
/// sides.
fn has_word(hay: &str, word: &str) -> bool {
    let h = hay.as_bytes();
    let mut start = 0;
    while let Some(p) = hay[start..].find(word) {
        let p = start + p;
        let left_ok = p == 0 || !is_ident_byte(h[p - 1]);
        let end = p + word.len();
        let right_ok = end >= h.len() || !is_ident_byte(h[end]);
        if left_ok && right_ok {
            return true;
        }
        start = p + 1;
    }
    false
}

fn mentions_hash_type(line: &str) -> bool {
    has_word(line, "HashMap") || has_word(line, "HashSet")
}

// ---------------------------------------------------------------------
// pass 1: order-determinism
// ---------------------------------------------------------------------

/// Collect identifiers declared with a hash-collection type: per file
/// (every `name: ..Hash..` and `let name = ..Hash..` form) and globally
/// (public fields only — the names that cross file boundaries, like
/// `Solution::tiles`).
fn hash_idents(
    files: &[SourceFile],
) -> (BTreeSet<String>, BTreeMap<String, BTreeSet<String>>) {
    let mut global = BTreeSet::new();
    let mut per_file: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    for f in files {
        let mine = per_file.entry(f.rel.clone()).or_default();
        for line in f.code.iter().take(f.test_start) {
            if !mentions_hash_type(line) {
                continue;
            }
            let trimmed = line.trim_start();
            if trimmed.starts_with("use ") {
                continue;
            }
            if let Some(ident) = let_binding_ident(trimmed) {
                mine.insert(ident);
            }
            for ty in ["HashMap", "HashSet"] {
                let mut start = 0;
                while let Some(p) = line[start..].find(ty) {
                    let p = start + p;
                    if let Some(ident) = typed_ident_before(line, p) {
                        if line.contains("pub ") {
                            global.insert(ident.clone());
                        }
                        mine.insert(ident);
                    }
                    start = p + 1;
                }
            }
        }
    }
    (global, per_file)
}

/// `let [mut] name` at the start of a line that mentions a hash type.
fn let_binding_ident(trimmed: &str) -> Option<String> {
    let rest = trimmed.strip_prefix("let ")?;
    let rest = rest.strip_prefix("mut ").unwrap_or(rest);
    let b = rest.as_bytes();
    let end = b.iter().position(|&c| !is_ident_byte(c)).unwrap_or(b.len());
    if end == 0 || b[0].is_ascii_digit() {
        return None;
    }
    Some(rest[..end].to_string())
}

/// The identifier annotated with the type at byte `p`: walks back over
/// the type expression to its `:` (skipping `::` path separators), then
/// takes the identifier before it.  `None` when the text between is not
/// type-like (e.g. a `-> HashSet<..>` return position).
fn typed_ident_before(line: &str, p: usize) -> Option<String> {
    let b = line.as_bytes();
    let mut search_end = p;
    let colon = loop {
        let c = line[..search_end].rfind(':')?;
        if c > 0 && b[c - 1] == b':' {
            search_end = c - 1;
            continue;
        }
        break c;
    };
    let between = &line[colon + 1..p];
    if !between
        .bytes()
        .all(|c| c.is_ascii_alphanumeric() || b" \t<&'(),[]_:".contains(&c))
    {
        return None;
    }
    let mut s = colon;
    while s > 0 && is_ident_byte(b[s - 1]) {
        s -= 1;
    }
    let ident = &line[s..colon];
    if ident.is_empty() || ident.as_bytes()[0].is_ascii_digit() {
        return None;
    }
    Some(ident.to_string())
}

/// Receiver expression of a method call ending at byte `dot` (the `.`):
/// the maximal run of identifier bytes, `.`, `[`, `]` before it.
fn receiver_before(line: &str, dot: usize) -> &str {
    let b = line.as_bytes();
    let mut s = dot;
    while s > 0 {
        let c = b[s - 1];
        if is_ident_byte(c) || c == b'.' || c == b'[' || c == b']' {
            s -= 1;
        } else {
            break;
        }
    }
    &line[s..dot]
}

fn pass_order_determinism(
    files: &[SourceFile],
    global: &BTreeSet<String>,
    per_file: &BTreeMap<String, BTreeSet<String>>,
) -> Vec<Finding> {
    let mut findings = Vec::new();
    for f in files.iter().filter(|f| f.watched()) {
        let empty = BTreeSet::new();
        let mine = per_file.get(&f.rel).unwrap_or(&empty);
        let known = |expr: &str| {
            mentions_hash_type(expr)
                || global.iter().chain(mine.iter()).any(|id| has_word(expr, id))
        };
        for (i, line) in f.code.iter().enumerate().take(f.test_start) {
            let mut hit = false;
            for m in ITER_METHODS {
                let mut start = 0;
                while let Some(p) = line[start..].find(m) {
                    let p = start + p;
                    if known(receiver_before(line, p)) {
                        hit = true;
                    }
                    start = p + 1;
                }
            }
            let trimmed = line.trim_start();
            if trimmed.starts_with("for ") {
                if let Some(pos) = trimmed.find(" in ") {
                    let expr = trimmed[pos + 4..].trim_end_matches('{').trim();
                    if known(expr) {
                        hit = true;
                    }
                }
            }
            if hit && !order_site_ok(f, i) {
                findings.push(Finding {
                    pass: "order-determinism",
                    file: f.rel.clone(),
                    line: i + 1,
                    message: format!(
                        "hash-collection iteration in a serialized-output module needs a \
                         following sort or a `// lint: order-insensitive` justification: \
                         `{}`",
                        line.trim()
                    ),
                });
            }
        }
    }
    findings
}

/// A flagged iteration site is fine if annotated (same line or the two
/// comment lines above) or if a sort lands within [`SORT_WINDOW`] lines.
fn order_site_ok(f: &SourceFile, i: usize) -> bool {
    if annotated(f, i, "lint: order-insensitive") {
        return true;
    }
    f.code[i..=(i + SORT_WINDOW).min(f.code.len() - 1)]
        .iter()
        .any(|l| l.contains(".sort"))
}

/// `tag` on the site's own line or in a comment within the two lines
/// above it.
fn annotated(f: &SourceFile, i: usize, tag: &str) -> bool {
    if f.raw[i].contains(tag) {
        return true;
    }
    (i.saturating_sub(2)..i).any(|j| {
        let t = f.raw[j].trim_start();
        t.starts_with("//") && t.contains(tag)
    })
}

// ---------------------------------------------------------------------
// pass 2: wall-clock hygiene
// ---------------------------------------------------------------------

fn pass_wall_clock(files: &[SourceFile]) -> Vec<Finding> {
    let mut findings = Vec::new();
    for f in files {
        let file_annotated = f.raw.iter().any(|l| l.contains("lint: wall-clock-file"));
        for (i, line) in f.code.iter().enumerate().take(f.test_start) {
            if has_word(line, "SystemTime") {
                findings.push(Finding {
                    pass: "wall-clock",
                    file: f.rel.clone(),
                    line: i + 1,
                    message: "SystemTime is banned: reports are byte-compared across runs"
                        .to_string(),
                });
            }
            if line.contains("Instant::now") && f.watched() && !file_annotated
                && !annotated(f, i, "lint: wall-clock")
            {
                findings.push(Finding {
                    pass: "wall-clock",
                    file: f.rel.clone(),
                    line: i + 1,
                    message: "Instant::now in a serialized-output module needs a \
                              `// lint: wall-clock` justification (readings must only \
                              reach fields zeroed by zero_wall_clock)"
                        .to_string(),
                });
            }
        }
    }
    findings.extend(check_zero_wall_clock(files));
    findings
}

/// Structural check of `MethodReport::zero_wall_clock`: its body must
/// mention every token of the wall-clock field manifest.
fn check_zero_wall_clock(files: &[SourceFile]) -> Vec<Finding> {
    let Some(f) = files.iter().find(|f| f.rel == "coordinator/metrics.rs") else {
        return vec![Finding {
            pass: "wall-clock",
            file: "coordinator/metrics.rs".to_string(),
            line: 1,
            message: "file not found (zero_wall_clock manifest check)".to_string(),
        }];
    };
    let Some(start) = f.code.iter().position(|l| l.contains("fn zero_wall_clock")) else {
        return vec![Finding {
            pass: "wall-clock",
            file: f.rel.clone(),
            line: 1,
            message: "fn zero_wall_clock not found".to_string(),
        }];
    };
    // brace-match the function body
    let mut depth = 0i32;
    let mut entered = false;
    let mut body = String::new();
    for line in &f.code[start..] {
        for c in line.bytes() {
            match c {
                b'{' => {
                    depth += 1;
                    entered = true;
                }
                b'}' => depth -= 1,
                _ => {}
            }
        }
        body.push_str(line);
        body.push('\n');
        if entered && depth == 0 {
            break;
        }
    }
    ZERO_WALL_CLOCK_MANIFEST
        .iter()
        .filter(|tok| !body.contains(*tok))
        .map(|tok| Finding {
            pass: "wall-clock",
            file: f.rel.clone(),
            line: start + 1,
            message: format!(
                "zero_wall_clock does not touch `{tok}` — a wall-clock field family \
                 escaped normalization (or the manifest in xtask/src/main.rs is stale)"
            ),
        })
        .collect()
}

// ---------------------------------------------------------------------
// pass 3: unsafe discipline
// ---------------------------------------------------------------------

fn pass_unsafe_discipline(files: &[SourceFile]) -> Vec<Finding> {
    let mut findings = Vec::new();
    for f in files {
        let allowed = UNSAFE_ALLOWLIST.contains(&f.rel.as_str());
        for (i, line) in f.code.iter().enumerate() {
            if !has_word(line, "unsafe") {
                continue;
            }
            if !allowed {
                findings.push(Finding {
                    pass: "unsafe",
                    file: f.rel.clone(),
                    line: i + 1,
                    message: "unsafe outside the codec/runtime allowlist".to_string(),
                });
            } else if !safety_documented(f, i) {
                findings.push(Finding {
                    pass: "unsafe",
                    file: f.rel.clone(),
                    line: i + 1,
                    message: format!(
                        "unsafe without a `// SAFETY:` (or `# Safety` doc) within {SAFETY_WINDOW} \
                         lines above"
                    ),
                });
            }
        }
    }
    findings.extend(check_lib_attributes(files));
    findings
}

fn safety_documented(f: &SourceFile, i: usize) -> bool {
    (i.saturating_sub(SAFETY_WINDOW)..=i)
        .any(|j| f.raw[j].contains("SAFETY:") || f.raw[j].contains("# Safety"))
}

/// `lib.rs` must pin the per-module unsafe posture: `forbid(unsafe_code)`
/// everywhere, except `deny(unsafe_op_in_unsafe_fn)` on the two modules
/// of the allowlist.
fn check_lib_attributes(files: &[SourceFile]) -> Vec<Finding> {
    let Some(f) = files.iter().find(|f| f.rel == "lib.rs") else {
        return vec![Finding {
            pass: "unsafe",
            file: "lib.rs".to_string(),
            line: 1,
            message: "lib.rs not found (module attribute check)".to_string(),
        }];
    };
    let mut findings = Vec::new();
    for (i, line) in f.code.iter().enumerate() {
        let trimmed = line.trim();
        let Some(name) = trimmed.strip_prefix("pub mod ").and_then(|r| r.strip_suffix(';'))
        else {
            continue;
        };
        let expected = if name == "codec" || name == "runtime" {
            "#[deny(unsafe_op_in_unsafe_fn)]"
        } else {
            "#[forbid(unsafe_code)]"
        };
        let found = (i.saturating_sub(2)..i).any(|j| f.code[j].trim() == expected);
        if !found {
            findings.push(Finding {
                pass: "unsafe",
                file: f.rel.clone(),
                line: i + 1,
                message: format!("`pub mod {name}` is missing its `{expected}` attribute"),
            });
        }
    }
    findings
}
