//! Offline stub of the [loom](https://github.com/tokio-rs/loom) model
//! checker (DESIGN.md §11).
//!
//! The repo's build is fully offline, so — like `third_party/xla-stub` —
//! the subset of loom's API the epoch-publication models use is
//! reimplemented in-tree: [`model`] runs a closure under **every
//! sequentially-consistent interleaving** of its visible operations and
//! panics (replaying the failing schedule's trace) if any interleaving
//! panics or deadlocks.
//!
//! # How it explores
//!
//! Exactly one logical thread runs at a time (a token passed through one
//! scheduler mutex, which also provides the happens-before edges that
//! make the shared-state handoff sound).  Before every **visible
//! operation** — mutex acquire, condvar wait/notify, spawn, join, thread
//! exit — the scheduler picks which runnable thread performs the next
//! one.  Each pick is recorded as `(choice, n_candidates)`; when a run
//! completes, the deepest pick with an unexplored sibling is bumped and
//! the program replays from the start down that branch (depth-first over
//! decision vectors), until no pick anywhere has an untried alternative.
//! A run with no runnable thread and unfinished threads is reported as a
//! deadlock — which is how a lost wakeup manifests.
//!
//! # Subset semantics
//!
//! * Sequential consistency only: no weak-memory reorderings, no
//!   `UnsafeCell`/atomics instrumentation — protocols must share state
//!   through [`sync::Mutex`]/[`sync::Condvar`] to be checked.
//! * No spurious condvar wakeups; `notify_one` wakes the lowest-id
//!   waiter (real loom branches over the choice).
//! * [`sync::Arc`] is `std`'s — immutable payloads behind an `Arc` need
//!   no modeling.
//!
//! Mutex unlock is *not* a decision point: a correct model only shares
//! data through these primitives, so the schedule between an unlock and
//! the unlocking thread's next visible op is observationally equivalent
//! for every other thread.

use std::cell::{RefCell, UnsafeCell};
use std::panic::{self, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc as StdArc, Condvar as StdCondvar, Mutex as StdMutex, MutexGuard as StdGuard};

/// Sentinel panic payload used to unwind parked threads when a run
/// aborts (a real panic elsewhere, or a deadlock); never user-visible.
struct AbortRun;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ThreadState {
    Runnable,
    /// Parked until the mutex is free.
    BlockedMutex(usize),
    /// Parked on a condvar; a notify re-parks the thread on its mutex.
    BlockedCondvar { cv: usize, mutex: usize },
    /// Parked until the target thread finishes.
    BlockedJoin(usize),
    Finished,
}

struct SchedState {
    threads: Vec<ThreadState>,
    /// Logical thread holding the run token (`usize::MAX` = none; the
    /// run is over or aborting).
    running: usize,
    /// Mutex id → owning thread.
    mutexes: Vec<Option<usize>>,
    n_condvars: usize,
    /// Decision vector of this run: `(choice, n_candidates)` per pick.
    trace: Vec<(usize, usize)>,
    /// Choices to replay before exploring first-candidate-first.
    prefix: Vec<usize>,
    step: usize,
    aborting: bool,
    panic_payload: Option<Box<dyn std::any::Any + Send>>,
    deadlock: Option<String>,
}

struct Scheduler {
    state: StdMutex<SchedState>,
    cv: StdCondvar,
}

thread_local! {
    static CURRENT: RefCell<Option<(StdArc<Scheduler>, usize)>> = const { RefCell::new(None) };
}

fn current() -> (StdArc<Scheduler>, usize) {
    CURRENT
        .with(|c| c.borrow().clone())
        .expect("loom primitives may only be used inside loom::model")
}

impl Scheduler {
    fn new(prefix: Vec<usize>) -> Scheduler {
        Scheduler {
            state: StdMutex::new(SchedState {
                threads: vec![ThreadState::Runnable],
                running: 0,
                mutexes: Vec::new(),
                n_condvars: 0,
                trace: Vec::new(),
                prefix,
                step: 0,
                aborting: false,
                panic_payload: None,
                deadlock: None,
            }),
            cv: StdCondvar::new(),
        }
    }

    /// The scheduler mutex ignores poisoning: threads unwind out of
    /// `wait_my_turn` (dropping the guard mid-panic) as part of the
    /// normal abort path.
    fn lock_state(&self) -> StdGuard<'_, SchedState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn enabled(st: &SchedState, tid: usize) -> bool {
        match st.threads[tid] {
            ThreadState::Runnable => true,
            ThreadState::BlockedMutex(m) => st.mutexes[m].is_none(),
            ThreadState::BlockedCondvar { .. } => false,
            ThreadState::BlockedJoin(t) => st.threads[t] == ThreadState::Finished,
            ThreadState::Finished => false,
        }
    }

    /// One scheduling decision: pick the next thread to run among the
    /// enabled ones, recording the branch.  The chosen thread is marked
    /// `Runnable` — its blocking condition was part of enabledness, and
    /// nothing can run between the pick and its resumption.
    fn pick_next(&self, st: &mut SchedState) {
        let enabled: Vec<usize> =
            (0..st.threads.len()).filter(|&t| Scheduler::enabled(st, t)).collect();
        if enabled.is_empty() {
            if st.threads.iter().all(|&t| t == ThreadState::Finished) {
                st.running = usize::MAX;
                return;
            }
            st.deadlock = Some(format!(
                "deadlock: no runnable thread (states {:?}, trace {:?})",
                st.threads, st.trace
            ));
            st.aborting = true;
            st.running = usize::MAX;
            return;
        }
        let choice = if st.step < st.prefix.len() { st.prefix[st.step] } else { 0 };
        assert!(
            choice < enabled.len(),
            "loom-stub: non-deterministic model (replay diverged: choice {choice} of {} candidates)",
            enabled.len()
        );
        st.trace.push((choice, enabled.len()));
        st.step += 1;
        let next = enabled[choice];
        st.threads[next] = ThreadState::Runnable;
        st.running = next;
    }

    /// Park until this thread holds the run token (or the run aborts).
    fn wait_my_turn<'a>(
        &'a self,
        mut st: StdGuard<'a, SchedState>,
        me: usize,
    ) -> StdGuard<'a, SchedState> {
        loop {
            if st.aborting {
                drop(st);
                panic::panic_any(AbortRun);
            }
            if st.running == me {
                return st;
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Run one visible operation for thread `me`: a scheduling decision,
    /// then the op.  `op` returns `None` to park (having set `me`'s
    /// blocked state); it is retried when the scheduler hands the token
    /// back, which it only does once the blocking condition cleared.
    fn visible_op<R>(&self, me: usize, mut op: impl FnMut(&mut SchedState) -> Option<R>) -> R {
        let mut st = self.lock_state();
        st.threads[me] = ThreadState::Runnable;
        self.pick_next(&mut st);
        self.cv.notify_all();
        st = self.wait_my_turn(st, me);
        loop {
            if let Some(r) = op(&mut st) {
                return r;
            }
            self.pick_next(&mut st);
            self.cv.notify_all();
            st = self.wait_my_turn(st, me);
        }
    }

    fn register_mutex(&self) -> usize {
        let mut st = self.lock_state();
        st.mutexes.push(None);
        st.mutexes.len() - 1
    }

    fn register_condvar(&self) -> usize {
        let mut st = self.lock_state();
        st.n_condvars += 1;
        st.n_condvars - 1
    }

    fn register_thread(&self) -> usize {
        let mut st = self.lock_state();
        st.threads.push(ThreadState::Runnable);
        st.threads.len() - 1
    }

    /// Thread exit: a real panic payload aborts the whole run.
    fn finish(&self, me: usize, panicked: Option<Box<dyn std::any::Any + Send>>) {
        let mut st = self.lock_state();
        st.threads[me] = ThreadState::Finished;
        if let Some(p) = panicked {
            st.aborting = true;
            if st.panic_payload.is_none() {
                st.panic_payload = Some(p);
            }
            st.running = usize::MAX;
        } else if st.running == me {
            self.pick_next(&mut st);
        }
        drop(st);
        self.cv.notify_all();
    }

    /// Atomic condvar wait: release the mutex and park in one step (no
    /// decision point in between — the real primitive guarantees this),
    /// then re-acquire once notified and rescheduled.
    fn condvar_wait(&self, me: usize, cv_id: usize, mutex_id: usize) {
        let mut st = self.lock_state();
        if st.aborting {
            drop(st);
            panic::panic_any(AbortRun);
        }
        debug_assert_eq!(st.mutexes[mutex_id], Some(me));
        st.mutexes[mutex_id] = None;
        st.threads[me] = ThreadState::BlockedCondvar { cv: cv_id, mutex: mutex_id };
        self.pick_next(&mut st);
        self.cv.notify_all();
        st = self.wait_my_turn(st, me);
        // the scheduler only picked us once the mutex was free, and no
        // other thread has run since the pick
        st.mutexes[mutex_id] = Some(me);
    }

    fn wait_all_finished(&self) {
        let mut st = self.lock_state();
        while !st.threads.iter().all(|&t| t == ThreadState::Finished) {
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }
}

pub mod sync {
    use super::*;

    pub use std::sync::Arc;

    /// Model-checked mutex.  The payload lives in an `UnsafeCell`;
    /// exclusivity is the scheduler's logical ownership (one thread runs
    /// at a time, and a guard only exists while its thread owns the
    /// mutex id), with happens-before provided by the scheduler's own
    /// lock on every handoff.
    pub struct Mutex<T> {
        id: usize,
        data: UnsafeCell<T>,
    }

    // SAFETY: access to `data` is serialized by the scheduler — a
    // `MutexGuard` is only handed to the single running thread after it
    // acquired logical ownership under the scheduler's std mutex, which
    // also carries the memory fence between consecutive owners.
    unsafe impl<T: Send> Send for Mutex<T> {}
    // SAFETY: as above — `&Mutex<T>` only yields `&T`/`&mut T` through a
    // guard, and guards are exclusive across threads by construction.
    unsafe impl<T: Send> Sync for Mutex<T> {}

    pub struct MutexGuard<'a, T> {
        mutex: &'a Mutex<T>,
    }

    impl<T> Mutex<T> {
        /// Register a mutex with the ambient scheduler: like every loom
        /// primitive, only constructible inside [`crate::model`].
        #[allow(clippy::new_without_default)]
        pub fn new(value: T) -> Mutex<T> {
            let (sched, _) = current();
            Mutex { id: sched.register_mutex(), data: UnsafeCell::new(value) }
        }

        pub fn lock(&self) -> std::sync::LockResult<MutexGuard<'_, T>> {
            let (sched, me) = current();
            sched.visible_op(me, |st| {
                if st.mutexes[self.id].is_none() {
                    st.mutexes[self.id] = Some(me);
                    Some(())
                } else {
                    st.threads[me] = ThreadState::BlockedMutex(self.id);
                    None
                }
            });
            Ok(MutexGuard { mutex: self })
        }
    }

    impl<T> std::ops::Deref for MutexGuard<'_, T> {
        type Target = T;
        fn deref(&self) -> &T {
            // SAFETY: this guard's thread holds the scheduler-tracked
            // ownership of `mutex.id` until drop; no other guard exists.
            unsafe { &*self.mutex.data.get() }
        }
    }

    impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
        fn deref_mut(&mut self) -> &mut T {
            // SAFETY: as in `deref` — exclusive logical ownership.
            unsafe { &mut *self.mutex.data.get() }
        }
    }

    impl<T> Drop for MutexGuard<'_, T> {
        fn drop(&mut self) {
            let (sched, me) = current();
            let mut st = sched.lock_state();
            debug_assert_eq!(st.mutexes[self.mutex.id], Some(me));
            st.mutexes[self.mutex.id] = None;
        }
    }

    pub struct Condvar {
        id: usize,
    }

    impl Condvar {
        #[allow(clippy::new_without_default)]
        pub fn new() -> Condvar {
            let (sched, _) = current();
            Condvar { id: sched.register_condvar() }
        }

        pub fn wait<'a, T>(
            &self,
            guard: MutexGuard<'a, T>,
        ) -> std::sync::LockResult<MutexGuard<'a, T>> {
            let (sched, me) = current();
            let mutex = guard.mutex;
            // released by the scheduler inside `condvar_wait`, not by
            // the guard's destructor
            std::mem::forget(guard);
            sched.condvar_wait(me, self.id, mutex.id);
            Ok(MutexGuard { mutex })
        }

        pub fn notify_all(&self) {
            let (sched, me) = current();
            sched.visible_op(me, |st| {
                for t in 0..st.threads.len() {
                    if let ThreadState::BlockedCondvar { cv, mutex } = st.threads[t] {
                        if cv == self.id {
                            st.threads[t] = ThreadState::BlockedMutex(mutex);
                        }
                    }
                }
                Some(())
            });
        }

        /// Wakes the lowest-id waiter (real loom branches over which).
        pub fn notify_one(&self) {
            let (sched, me) = current();
            sched.visible_op(me, |st| {
                for t in 0..st.threads.len() {
                    if let ThreadState::BlockedCondvar { cv, mutex } = st.threads[t] {
                        if cv == self.id {
                            st.threads[t] = ThreadState::BlockedMutex(mutex);
                            break;
                        }
                    }
                }
                Some(())
            });
        }
    }
}

pub mod thread {
    use super::*;

    pub struct JoinHandle<T> {
        tid: usize,
        rx: mpsc::Receiver<T>,
        os: Option<std::thread::JoinHandle<()>>,
    }

    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        let (sched, me) = current();
        let tid = sched.register_thread();
        let (tx, rx) = mpsc::channel();
        let child_sched = StdArc::clone(&sched);
        let os = std::thread::Builder::new()
            .name(format!("loom-{tid}"))
            .spawn(move || {
                CURRENT.with(|c| *c.borrow_mut() = Some((StdArc::clone(&child_sched), tid)));
                // park until first scheduled
                let st = child_sched.lock_state();
                let result = panic::catch_unwind(AssertUnwindSafe(|| {
                    let st = child_sched.wait_my_turn(st, tid);
                    drop(st);
                    f()
                }));
                match result {
                    Ok(v) => {
                        // the value is on the channel before Finished is
                        // visible, so join's try_recv below cannot miss
                        let _ = tx.send(v);
                        child_sched.finish(tid, None);
                    }
                    Err(e) if e.is::<AbortRun>() => child_sched.finish(tid, None),
                    Err(e) => child_sched.finish(tid, Some(e)),
                }
            })
            .expect("spawn loom model thread");
        // the spawn itself is a visible op: the child is runnable from
        // here on, and may be scheduled before the parent continues
        sched.visible_op(me, |_| Some(()));
        JoinHandle { tid, rx, os: Some(os) }
    }

    impl<T> JoinHandle<T> {
        pub fn join(mut self) -> std::thread::Result<T> {
            let (sched, me) = current();
            sched.visible_op(me, |st| {
                if st.threads[self.tid] == ThreadState::Finished {
                    Some(())
                } else {
                    st.threads[me] = ThreadState::BlockedJoin(self.tid);
                    None
                }
            });
            // the logical thread is finished: its OS thread makes no
            // further scheduler calls, so a blocking join is safe
            if let Some(os) = self.os.take() {
                let _ = os.join();
            }
            self.rx
                .try_recv()
                .map_err(|_| Box::new("loom model thread panicked") as Box<dyn std::any::Any + Send>)
        }
    }
}

fn run_once(
    f: &StdArc<dyn Fn() + Send + Sync>,
    prefix: &[usize],
) -> (Vec<(usize, usize)>, Option<Box<dyn std::any::Any + Send>>, Option<String>) {
    let sched = StdArc::new(Scheduler::new(prefix.to_vec()));
    let f = StdArc::clone(f);
    let s = StdArc::clone(&sched);
    let main = std::thread::Builder::new()
        .name("loom-0".to_string())
        .spawn(move || {
            CURRENT.with(|c| *c.borrow_mut() = Some((StdArc::clone(&s), 0)));
            let result = panic::catch_unwind(AssertUnwindSafe(|| f()));
            match result {
                Ok(()) => s.finish(0, None),
                Err(e) if e.is::<AbortRun>() => s.finish(0, None),
                Err(e) => s.finish(0, Some(e)),
            }
        })
        .expect("spawn loom model main thread");
    let _ = main.join();
    sched.wait_all_finished();
    let mut st = sched.lock_state();
    (st.trace.clone(), st.panic_payload.take(), st.deadlock.take())
}

/// Exhaustively run `f` under every sequentially-consistent
/// interleaving of its visible operations.  Panics — replaying the
/// failing decision vector — if any interleaving panics or deadlocks.
pub fn model<F>(f: F)
where
    F: Fn() + Send + Sync + 'static,
{
    let f: StdArc<dyn Fn() + Send + Sync> = StdArc::new(f);
    let max_iters: u64 = std::env::var("CROSSROI_LOOM_MAX_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1_000_000);
    let mut prefix: Vec<usize> = Vec::new();
    let mut iters: u64 = 0;
    loop {
        iters += 1;
        let (trace, panicked, deadlock) = run_once(&f, &prefix);
        if let Some(msg) = deadlock {
            panic!("loom-stub: {msg} (interleaving {iters})");
        }
        if let Some(p) = panicked {
            eprintln!(
                "loom-stub: interleaving {iters} failed; decision vector {:?}",
                trace.iter().map(|&(c, _)| c).collect::<Vec<_>>()
            );
            panic::resume_unwind(p);
        }
        // deepest decision with an unexplored sibling → next branch
        match trace.iter().rposition(|&(c, n)| c + 1 < n) {
            None => break,
            Some(p) => {
                prefix.clear();
                prefix.extend(trace[..p].iter().map(|&(c, _)| c));
                prefix.push(trace[p].0 + 1);
            }
        }
        assert!(
            iters < max_iters,
            "loom-stub: model exceeded {max_iters} interleavings; shrink it or raise CROSSROI_LOOM_MAX_ITERS"
        );
    }
    eprintln!("loom-stub: explored {iters} interleavings exhaustively");
}
