//! Offline stand-in for the PJRT `xla` bindings (DESIGN.md §3).
//!
//! The real deployment points the workspace's optional `xla` dependency
//! at the genuine PJRT CPU-client bindings; this container has no
//! network, so the `pjrt` feature compiles against this API-compatible
//! stub instead.  Every entry point that would touch PJRT returns a
//! descriptive error — the surrounding code (artifact loading, the
//! `crossroi info` subcommand) already treats runtime unavailability as
//! a soft failure.

use std::fmt;

/// Error type matching the real bindings' surface (Display + Error).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT is unavailable in this build (the `xla` dependency is the offline stub; \
         point it at the real bindings to run compiled artifacts)"
    ))
}

/// Scalar element types the [`Literal`] constructors accept.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// A host-side tensor.
#[derive(Debug, Clone, Default)]
pub struct Literal {
    bytes: Vec<u8>,
    dims: Vec<i64>,
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let bytes = unsafe {
            std::slice::from_raw_parts(data.as_ptr().cast::<u8>(), std::mem::size_of_val(data))
        }
        .to_vec();
        Literal { bytes, dims: vec![data.len() as i64] }
    }

    /// Reshape to `dims` (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        Ok(Literal { bytes: self.bytes.clone(), dims: dims.to_vec() })
    }

    /// Unwrap a single-element tuple result.
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// A parsed HLO module.
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        // match the real bindings: missing files fail at parse time
        std::fs::metadata(path)
            .map_err(|e| Error(format!("reading HLO text {path}: {e}")))?;
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// A computation ready for compilation.
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A device-resident buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given arguments; returns per-device, per-output
    /// buffers like the real bindings.
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// The PJRT client.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literals_carry_shape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.dims(), &[6]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
    }

    #[test]
    fn pjrt_entry_points_error_descriptively() {
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("offline stub"), "{err}");
    }
}
