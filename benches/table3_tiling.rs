//! Table 3 — efficacy characterization of tile-based video compression:
//! encode every camera's profile clip as one whole-frame region vs split
//! into m×n independent tiles; report sizes and the amplification factor.
//!
//! Expected shape (paper): sizes grow monotonically with tile fineness;
//! amplification 1.01–1.17× from original to 8×8.

mod common;

use crossroi::bench::{fmt, Table};
use crossroi::codec::SegmentEncoder;
use crossroi::sim::Scenario;
use crossroi::util::geometry::IRect;

fn main() {
    let cfg = common::bench_config();
    let scenario = Scenario::build(&cfg.scenario);
    let renderer = scenario.renderer();
    let n_frames = scenario.profile_range().len().min(120);
    let fps = cfg.scenario.fps;
    let frames_per_segment = (cfg.system.segment_secs * fps).round() as usize;
    let splits: [(u32, u32); 6] = [(1, 1), (2, 2), (2, 4), (4, 4), (4, 8), (8, 8)];
    println!(
        "encoding {} frames per camera, {}-frame GOPs, qp={}",
        n_frames, frames_per_segment, cfg.system.qp
    );

    let headers: Vec<String> = std::iter::once("camera".to_string())
        .chain(splits.iter().map(|(m, n)| {
            if (*m, *n) == (1, 1) {
                "original".to_string()
            } else {
                format!("{m}x{n}")
            }
        }))
        .collect();
    let mut table = Table::new(&headers.iter().map(|s| s.as_str()).collect::<Vec<_>>());

    for cam in 0..scenario.cameras.len() {
        let frames: Vec<_> = (0..n_frames).map(|f| renderer.render(cam, f)).collect();
        let mut row = vec![format!("C{}", cam + 1)];
        let mut base = 0usize;
        for &(m, n) in &splits {
            let (w, h) = (320 / n, 192 / m);
            let regions: Vec<IRect> = (0..m)
                .flat_map(|ty| (0..n).map(move |tx| IRect::new(tx * w, ty * h, w, h)))
                .collect();
            let mut enc = SegmentEncoder::new(&regions, cfg.system.qp);
            let mut bytes = 0usize;
            for chunk in frames.chunks(frames_per_segment) {
                bytes += enc.encode_segment(chunk).bytes;
            }
            if (m, n) == (1, 1) {
                base = bytes;
            }
            row.push(format!(
                "{} KB ({})",
                bytes / 1024,
                fmt(bytes as f64 / base as f64, 2)
            ));
        }
        table.row(row);
    }
    table.print("Table 3 — tile-split compression efficacy (size, amplification vs original)");
    println!("\nexpected shape: amplification grows monotonically toward 8x8 (paper: 1.01-1.17x)");
}
