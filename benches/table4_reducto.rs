//! Table 4 — Reducto vs CrossRoI-Reducto at accuracy targets
//! {1.00, 0.95, 0.90, 0.85}: accuracy achieved, frames reduced, network,
//! server throughput and end-to-end latency.
//!
//! Expected shape (paper): both meet their targets; CrossRoI-Reducto
//! dominates Reducto at every target (−40…−48 % network, 1.18–1.45×
//! throughput, −23…−26 % latency); target 1.00 degenerates to
//! Baseline / plain CrossRoI.

mod common;

use crossroi::bench::{fmt, Table};
use crossroi::coordinator::{baseline_reference, run_method, Method, RuntimeInfer};
use crossroi::sim::Scenario;

fn main() {
    let cfg = common::bench_config();
    let scenario = Scenario::build(&cfg.scenario);
    let rt = common::load_runtime(&cfg);
    let infer = RuntimeInfer(&rt);
    let targets = [1.0, 0.95, 0.90, 0.85];

    let (reference, _) = baseline_reference(&scenario, &cfg.system, &infer).unwrap();

    let mut table = Table::new(&[
        "system", "target", "acc achieved", "frames reduced", "net Mbps", "srv Hz", "e2e s",
    ]);
    let mut rows: Vec<(String, f64, crossroi::coordinator::MethodReport)> = Vec::new();
    for &t in &targets {
        for (name, method) in [
            ("Reducto", Method::Reducto(t)),
            ("CrossRoI-Reducto", Method::CrossRoiReducto(t)),
        ] {
            let r = run_method(&scenario, &cfg.system, &infer, &method, Some(&reference)).unwrap();
            table.row(vec![
                name.to_string(),
                fmt(t, 2),
                fmt(r.accuracy, 3),
                format!("{}/{}", r.frames_reduced, r.frames_total),
                fmt(r.network_mbps_total, 2),
                fmt(r.server_hz, 1),
                fmt(r.latency.total(), 3),
            ]);
            rows.push((name.to_string(), t, r));
        }
    }
    table.print("Table 4 — Reducto vs CrossRoI-Reducto");

    println!("\nshape checks (CrossRoI-Reducto vs Reducto at each target):");
    for &t in &targets {
        let red = &rows.iter().find(|(n, tt, _)| n == "Reducto" && *tt == t).unwrap().2;
        let cr = &rows
            .iter()
            .find(|(n, tt, _)| n == "CrossRoI-Reducto" && *tt == t)
            .unwrap()
            .2;
        println!(
            "  target {:.2}: net {:+.1}% (paper -40..-48%), srv {:.2}x (paper 1.18-1.45x), e2e {:+.1}% (paper -23..-26%)",
            t,
            100.0 * (cr.network_mbps_total / red.network_mbps_total - 1.0),
            cr.server_hz / red.server_hz,
            100.0 * (cr.latency.total() / red.latency.total() - 1.0),
        );
    }
}
