//! Table 2 — characterization of raw ReID results: TP/FP/FN/TN counts for
//! every (source, destination) camera pair over the profile window, plus
//! the same matrix after the tandem filters (showing what they remove).
//!
//! Expected shape (paper): FN ≫ FP per pair, TN dominant, true > false in
//! both classes (observation O2); after filtering, FP ≈ 0 and FN sharply
//! reduced.

mod common;

use crossroi::bench::Table;
use crossroi::filters::TandemFilters;
use crossroi::reid::error_model::{ErrorModelParams, RawReid};
use crossroi::reid::labels;
use crossroi::sim::Scenario;

fn print_matrix(title: &str, m: &[Vec<labels::PairCounts>]) {
    let n = m.len();
    let headers: Vec<String> = std::iter::once("S\\D".to_string())
        .chain((0..n).map(|d| format!("C{} TP/FP/FN/TN", d + 1)))
        .collect();
    let mut table = Table::new(&headers.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    for s in 0..n {
        let mut row = vec![format!("C{}", s + 1)];
        for d in 0..n {
            if s == d {
                row.push("-".into());
            } else {
                let c = m[s][d];
                row.push(format!("{}/{}/{}/{}", c.tp, c.fp, c.fn_, c.tn));
            }
        }
        table.row(row);
    }
    table.print(title);
}

fn main() {
    let cfg = common::bench_config();
    let scenario = Scenario::build(&cfg.scenario);
    let raw = RawReid::generate(&scenario, scenario.profile_range(), &ErrorModelParams::default());
    println!(
        "profile window: {} frames, {} raw ReID records",
        scenario.profile_range().len(),
        raw.len()
    );

    let before = labels::characterize_all(&raw);
    print_matrix("Table 2 — raw ReID characterization (before filters)", &before);

    let (clean, report) = TandemFilters::default().apply(&raw);
    let after = labels::characterize_all(&clean);
    print_matrix("Table 2b — after tandem filters (this repo's addition)", &after);
    println!(
        "\nfilters: {} pairs fit, {} FP decoupled, {} FN removed, {} -> {} records",
        report.pairs_fit,
        report.fp_rewritten,
        report.fn_removed,
        raw.len(),
        clean.len()
    );

    // shape checks mirroring the paper's observations (§4.2.1)
    let sum = |f: fn(&labels::PairCounts) -> usize, m: &[Vec<labels::PairCounts>]| -> usize {
        m.iter().flat_map(|r| r.iter()).map(f).sum()
    };
    let (tp, fp) = (sum(|c| c.tp, &before), sum(|c| c.fp, &before));
    let (fn_, tn) = (sum(|c| c.fn_, &before), sum(|c| c.tn, &before));
    println!("\nshape (raw): TP={tp} FP={fp} FN={fn_} TN={tn}");
    println!("  O2 true positives > false positives: {}", if tp > fp { "OK" } else { "VIOLATED" });
    println!("  O2 true negatives > false negatives: {}", if tn > fn_ { "OK" } else { "note: heavy-overlap rig" });
    let (fp2, fn2) = (sum(|c| c.fp, &after), sum(|c| c.fn_, &after));
    println!("shape (filtered): FP {fp} -> {fp2}, FN {fn_} -> {fn2}");
}
