//! Figure 9 — sensitivity to the SVM filter's kernel non-linearity γ:
//! accuracy, network overhead and end-to-end latency per γ.
//!
//! Expected shape (paper): accuracy, network and latency all *increase*
//! with γ — a small γ underfits, removes many negatives (including true
//! ones), shrinks masks (cheap but lossy); a huge γ memorizes, removes
//! nothing (expensive but safe).  Note our γ grid centers near 1 because
//! features are pre-scaled to O(1) (the paper's 1e-4 is on 1080p pixels).

mod common;

use crossroi::bench::{fmt, Table};
use crossroi::coordinator::{baseline_reference, run_method, Method, RuntimeInfer};
use crossroi::sim::Scenario;

fn main() {
    let cfg = common::sweep_config();
    let scenario = Scenario::build(&cfg.scenario);
    let rt = common::load_runtime(&cfg);
    let infer = RuntimeInfer(&rt);
    let gammas = [0.01, 0.1, 1.0, 10.0, 100.0];

    let (reference, _) = baseline_reference(&scenario, &cfg.system, &infer).unwrap();
    let mut table = Table::new(&["gamma", "accuracy", "net Mbps", "e2e s", "|M| tiles"]);
    let mut series = Vec::new();
    for &g in &gammas {
        let mut sys = cfg.system.clone();
        sys.svm_gamma = g;
        let r = run_method(&scenario, &sys, &infer, &Method::CrossRoi, Some(&reference)).unwrap();
        table.row(vec![
            format!("{g}"),
            fmt(r.accuracy, 4),
            fmt(r.network_mbps_total, 3),
            fmt(r.latency.total(), 3),
            r.mask_tiles.to_string(),
        ]);
        series.push((g, r));
    }
    table.print("Fig. 9 — sensitivity to SVM γ");
    let first = &series.first().unwrap().1;
    let last = &series.last().unwrap().1;
    println!(
        "\nshape: mask tiles {} (γ={}) -> {} (γ={}); paper: net & accuracy increase with γ",
        first.mask_tiles,
        series.first().unwrap().0,
        last.mask_tiles,
        series.last().unwrap().0
    );
}
