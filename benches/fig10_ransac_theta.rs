//! Figure 10 — sensitivity to the RANSAC residual-threshold multiplier θ:
//! accuracy, network overhead and end-to-end latency per θ.
//!
//! Expected shape (paper): accuracy, network and latency all *decrease*
//! as θ increases — a tiny θ flags many positives as outliers, decoupling
//! them into solo constraints (larger masks: safe but expensive); a large
//! θ trusts every association (small masks, but wrong matches leak in).

mod common;

use crossroi::bench::{fmt, Table};
use crossroi::coordinator::{baseline_reference, run_method, Method, RuntimeInfer};
use crossroi::sim::Scenario;

fn main() {
    let cfg = common::sweep_config();
    let scenario = Scenario::build(&cfg.scenario);
    let rt = common::load_runtime(&cfg);
    let infer = RuntimeInfer(&rt);
    let thetas = [0.05, 0.2, 0.5, 1.0, 2.0];

    let (reference, _) = baseline_reference(&scenario, &cfg.system, &infer).unwrap();
    let mut table = Table::new(&["theta", "accuracy", "net Mbps", "e2e s", "|M| tiles"]);
    let mut series = Vec::new();
    for &t in &thetas {
        let mut sys = cfg.system.clone();
        sys.ransac_theta = t;
        let r = run_method(&scenario, &sys, &infer, &Method::CrossRoi, Some(&reference)).unwrap();
        table.row(vec![
            format!("{t}"),
            fmt(r.accuracy, 4),
            fmt(r.network_mbps_total, 3),
            fmt(r.latency.total(), 3),
            r.mask_tiles.to_string(),
        ]);
        series.push((t, r));
    }
    table.print("Fig. 10 — sensitivity to RANSAC θ");
    let first = &series.first().unwrap().1;
    let last = &series.last().unwrap().1;
    println!(
        "\nshape: mask tiles {} (θ={}) -> {} (θ={}); paper: net & accuracy decrease with θ",
        first.mask_tiles,
        series.first().unwrap().0,
        last.mask_tiles,
        series.last().unwrap().0
    );
}
