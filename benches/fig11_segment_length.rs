//! Figure 11 — network/latency tradeoff vs streaming segment length.
//!
//! Expected shape (paper): longer segments compress better (network ↓
//! monotonically) but queue longer at the camera (latency ↑ roughly
//! linearly past the 1 s sweet spot); the paper picks 1 s.

mod common;

use crossroi::bench::{fmt, Table};
use crossroi::coordinator::{baseline_reference, run_method, Method, RuntimeInfer};
use crossroi::sim::Scenario;

fn main() {
    let cfg = common::sweep_config();
    let scenario = Scenario::build(&cfg.scenario);
    let rt = common::load_runtime(&cfg);
    let infer = RuntimeInfer(&rt);
    let lengths = [0.4, 1.0, 2.0, 4.0];

    let (reference, _) = baseline_reference(&scenario, &cfg.system, &infer).unwrap();
    let mut table = Table::new(&[
        "segment s", "net Mbps", "e2e s", "cam s", "net-lat s", "srv s",
    ]);
    let mut series = Vec::new();
    for &len in &lengths {
        let mut sys = cfg.system.clone();
        sys.segment_secs = len;
        let r = run_method(&scenario, &sys, &infer, &Method::CrossRoi, Some(&reference)).unwrap();
        table.row(vec![
            fmt(len, 1),
            fmt(r.network_mbps_total, 3),
            fmt(r.latency.total(), 3),
            fmt(r.latency.camera, 3),
            fmt(r.latency.network, 3),
            fmt(r.latency.server, 3),
        ]);
        series.push((len, r));
    }
    table.print("Fig. 11 — segment length: network vs latency tradeoff");
    let net_monotone = series.windows(2).all(|w| {
        w[1].1.network_mbps_total <= w[0].1.network_mbps_total * 1.02
    });
    println!(
        "\nshape: network decreases with segment length: {}",
        if net_monotone { "OK" } else { "VIOLATED" }
    );
    println!("       camera-side latency grows with segment length (queueing)");
}
