//! §4.4 claims extended to consolidation — the three-way crossover
//! between the dense detector, the SBNet-style per-camera RoI variant
//! and the cross-camera canvas route (DESIGN.md §13): sweep aggregate
//! RoI coverage on 16→64-camera fleets and record which route wins.
//!
//! Expected shape: canvas beats per-camera RoI below ~25 % aggregate
//! coverage (many mostly-empty inferences fold into a few dense ones);
//! near full-frame coverage every camera needs its own canvas, so the
//! gather overhead makes consolidation the *losing* route — which is why
//! the auto heuristic routes by coverage.
//!
//! The native sweep runs everywhere; with `--features pjrt` the original
//! measured SBNet-vs-dense table on the compiled executables follows.
//!
//! Besides the printed tables the bench writes `BENCH_canvas.json`
//! (machine-readable rows: fleet size, coverage, per-camera seconds per
//! route, canvas count, fill) so CI can archive the crossover per commit.
//!
//! Run: `cargo bench --bench sbnet_crossover`
//! Quick smoke (CI): `CROSSROI_BENCH_QUICK=1 cargo bench --bench sbnet_crossover`

mod common;

use crossroi::bench::{fmt, time_it, Table, Timing};
use crossroi::pipeline::canvas::{gather_into, inflate_clip, GATHER_INFLATE_CELLS, GUTTER_PX};
use crossroi::runtime::native::{detect_full_into, detect_roi_into, DetectScratch};
use crossroi::sim::Scenario;
use crossroi::tilegroup::pack::{PackItem, Packer, Placement};
use crossroi::util::geometry::IRect;
use crossroi::util::json::Json;

const FRAME_W: usize = 320;
const FRAME_H: usize = 192;
const FRAME_PX: u64 = (FRAME_W * FRAME_H) as u64;

/// One fleet/coverage point of the native three-way sweep.
struct FleetRow {
    cameras: usize,
    coverage_pct: f64,
    dense: Timing,
    sbnet: Timing,
    canvas: Timing,
    canvases: usize,
    mean_fill: f64,
}

/// A deterministic 16-aligned kept-group rect covering roughly
/// `coverage_pct` of the frame, shifted per camera so fleets don't pack
/// into degenerate identical layouts.
fn group_rect(cam: usize, coverage_pct: f64) -> IRect {
    let cells = ((coverage_pct / 100.0) * 240.0).round().max(1.0) as u32;
    let w_cells = cells.min(20);
    let h_cells = cells.div_ceil(w_cells).min(12);
    let x0 = (cam as u32 * 3) % (20 - w_cells + 1);
    let y0 = (cam as u32 * 5) % (12 - h_cells + 1);
    IRect::new(x0 * 16, y0 * 16, w_cells * 16, h_cells * 16)
}

/// The 32-px SBNet block ids covered by a rect (10-wide block grid).
fn rect_blocks(r: IRect) -> Vec<i32> {
    let mut out = Vec::new();
    for by in (r.y / 32)..(r.y + r.h).div_ceil(32) {
        for bx in (r.x / 32)..(r.x + r.w).div_ceil(32) {
            out.push((by * 10 + bx) as i32);
        }
    }
    out
}

fn main() {
    let quick = std::env::var("CROSSROI_BENCH_QUICK").ok().as_deref() == Some("1");
    let (warmup, iters, secs) = if quick { (1, 2, 0.5) } else { (2, 8, 3.0) };
    let fleets: &[usize] = if quick { &[8] } else { &[16, 32, 64] };
    let coverages: &[f64] = if quick { &[10.0, 50.0] } else { &[5.0, 10.0, 25.0, 50.0, 75.0] };

    let cfg = common::sweep_config();
    let scenario = Scenario::build(&cfg.scenario);
    let renderer = scenario.renderer();
    // one rendered frame per fleet slot (distinct timestamps stand in
    // for distinct cameras — identical detector cost either way)
    let max_cams = *fleets.iter().max().unwrap();
    let frames: Vec<Vec<f32>> = (0..max_cams).map(|i| renderer.render(0, i).to_f32()).collect();

    let mut rows: Vec<FleetRow> = Vec::new();
    for &n in fleets {
        for &cov in coverages {
            let rects: Vec<IRect> = (0..n).map(|c| group_rect(c, cov)).collect();
            let gathers: Vec<IRect> = rects
                .iter()
                .map(|&r| inflate_clip(r, GATHER_INFLATE_CELLS, FRAME_W as u32, FRAME_H as u32))
                .collect();
            let blocks: Vec<Vec<i32>> = rects.iter().map(|&r| rect_blocks(r)).collect();

            // epoch-time packing (not in the timed region — the pipeline
            // packs once per plan, not once per frame)
            let items: Vec<PackItem> = gathers
                .iter()
                .enumerate()
                .map(|(id, g)| PackItem { id, w: g.w, h: g.h })
                .collect();
            let mut packer = Packer::new(FRAME_W as u32, FRAME_H as u32, GUTTER_PX);
            let mut placements: Vec<Placement> = Vec::new();
            let n_canvases = packer.pack(&items, &mut placements);
            let placed_px: u64 = gathers.iter().map(|g| g.area()).sum();
            let mean_fill = placed_px as f64 / (n_canvases as u64 * FRAME_PX) as f64;

            // all buffers hoisted out of the timed closures
            let mut scratch = DetectScratch::new();
            let mut grid: Vec<f32> = Vec::new();
            let mut canvases: Vec<Vec<f32>> =
                vec![vec![0.0; FRAME_W * FRAME_H * 3]; n_canvases];

            let dense = time_it(warmup, iters, secs, || {
                for f in &frames[..n] {
                    detect_full_into(f, FRAME_H, FRAME_W, &mut scratch, &mut grid);
                    std::hint::black_box(&grid);
                }
            });
            let sbnet = time_it(warmup, iters, secs, || {
                for (f, b) in frames[..n].iter().zip(&blocks) {
                    detect_roi_into(f, FRAME_H, FRAME_W, b, 32, 10, &mut scratch, &mut grid);
                    std::hint::black_box(&grid);
                }
            });
            // gathers rewrite the same placements every iteration, so the
            // zero-initialised gutters stay zero across iterations
            let canvas = time_it(warmup, iters, secs, || {
                for p in &placements {
                    gather_into(
                        &mut canvases[p.canvas],
                        FRAME_W,
                        &frames[p.id],
                        FRAME_W,
                        gathers[p.id],
                        p.x,
                        p.y,
                    );
                }
                for c in &canvases {
                    detect_full_into(c, FRAME_H, FRAME_W, &mut scratch, &mut grid);
                    std::hint::black_box(&grid);
                }
            });
            rows.push(FleetRow {
                cameras: n,
                coverage_pct: cov,
                dense,
                sbnet,
                canvas,
                canvases: n_canvases,
                mean_fill,
            });
        }
    }

    let mut table = Table::new(&[
        "cameras", "coverage %", "dense/cam", "sbnet/cam", "canvas/cam",
        "canvases", "fill", "canvas vs sbnet",
    ]);
    for r in &rows {
        let per_cam = |t: &Timing| t.mean_secs / r.cameras as f64;
        table.row(vec![
            r.cameras.to_string(),
            fmt(r.coverage_pct, 0),
            format!("{:.1}us", per_cam(&r.dense) * 1e6),
            format!("{:.1}us", per_cam(&r.sbnet) * 1e6),
            format!("{:.1}us", per_cam(&r.canvas) * 1e6),
            r.canvases.to_string(),
            fmt(r.mean_fill, 2),
            format!("{:.2}x", r.sbnet.mean_secs / r.canvas.mean_secs),
        ]);
    }
    table.print("dense vs per-camera RoI vs consolidated canvases (native detector)");
    println!(
        "\nexpected shape: canvas > 1x vs sbnet at <=25% aggregate coverage, \
         < 1x near full coverage (three-way crossover)"
    );

    let json_rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("cameras", Json::Num(r.cameras as f64)),
                ("coverage_pct", Json::Num(r.coverage_pct)),
                ("dense_secs_per_cam", Json::Num(r.dense.mean_secs / r.cameras as f64)),
                ("sbnet_secs_per_cam", Json::Num(r.sbnet.mean_secs / r.cameras as f64)),
                ("canvas_secs_per_cam", Json::Num(r.canvas.mean_secs / r.cameras as f64)),
                ("canvases", Json::Num(r.canvases as f64)),
                ("mean_fill", Json::Num(r.mean_fill)),
                (
                    "canvas_speedup_vs_sbnet",
                    Json::Num(r.sbnet.mean_secs / r.canvas.mean_secs),
                ),
                ("iters", Json::Num(r.dense.iters as f64)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::Str("sbnet_crossover".into())),
        ("detector", Json::Str("native".into())),
        ("quick", Json::Bool(quick)),
        ("rows", Json::Arr(json_rows)),
    ]);
    let path = "BENCH_canvas.json";
    std::fs::write(path, doc.to_string_pretty(2) + "\n").expect("write crossover scoreboard");
    println!("crossover scoreboard written to {path}");

    // ---- measured SBNet-vs-dense sweep on the PJRT executables ----
    #[cfg(feature = "pjrt")]
    {
        let rt = common::load_runtime(&cfg);
        let frame = renderer.render(0, 5).to_f32();
        let dense = time_it(3, 40, 8.0, || {
            std::hint::black_box(rt.infer_full(&frame).unwrap());
        });
        println!(
            "\ndense detector: {} ({:.1} Hz)",
            dense.per_iter_display(),
            1.0 / dense.mean_secs
        );
        let mut table = Table::new(&[
            "active blocks", "coverage %", "per-frame", "Hz", "speedup vs dense",
        ]);
        for &k in &[4usize, 8, 12, 16, 24, 32, 48, 60] {
            let blocks: Vec<i32> = (0..k as i32).collect();
            let t = time_it(3, 40, 8.0, || {
                std::hint::black_box(rt.infer_roi(&frame, &blocks).unwrap());
            });
            table.row(vec![
                format!("{k} (K={})", rt.capacity_for(k).unwrap_or(60)),
                fmt(100.0 * k as f64 / 60.0, 0),
                t.per_iter_display(),
                fmt(1.0 / t.mean_secs, 1),
                fmt(dense.mean_secs / t.mean_secs, 2),
            ]);
        }
        table.print("SBNet RoI variant vs dense (measured on the PJRT executables)");
    }
}
