//! §4.4 claims — SBNet speedup vs RoI area and the dense crossover:
//! sweep the number of active blocks through every compiled RoI capacity
//! and compare against the dense detector.
//!
//! Expected shape (paper): 1.5–2.5× speedup at 10–20 % RoI coverage;
//! gather/scatter overhead makes RoI *slower* than dense near full-frame
//! coverage (why CrossRoI loads both models and routes by RoI area).

mod common;

use crossroi::bench::{fmt, time_it, Table};
use crossroi::sim::Scenario;

fn main() {
    let cfg = common::sweep_config();
    let scenario = Scenario::build(&cfg.scenario);
    let renderer = scenario.renderer();
    let rt = common::load_runtime(&cfg);
    let frame = renderer.render(0, 5).to_f32();

    let dense = time_it(3, 40, 8.0, || {
        std::hint::black_box(rt.infer_full(&frame).unwrap());
    });
    println!(
        "dense detector: {} ({:.1} Hz)",
        dense.per_iter_display(),
        1.0 / dense.mean_secs
    );

    let mut table = Table::new(&[
        "active blocks", "coverage %", "per-frame", "Hz", "speedup vs dense",
    ]);
    for &n in &[4usize, 8, 12, 16, 24, 32, 48, 60] {
        let blocks: Vec<i32> = (0..n as i32).collect();
        let t = time_it(3, 40, 8.0, || {
            std::hint::black_box(rt.infer_roi(&frame, &blocks).unwrap());
        });
        table.row(vec![
            format!("{n} (K={})", rt.capacity_for(n).unwrap_or(60)),
            fmt(100.0 * n as f64 / 60.0, 0),
            t.per_iter_display(),
            fmt(1.0 / t.mean_secs, 1),
            fmt(dense.mean_secs / t.mean_secs, 2),
        ]);
    }
    table.print("SBNet RoI variant vs dense (measured on the PJRT executables)");
    println!("\nexpected shape: speedup > 1.5x below ~20% coverage, < 1x near 100% (crossover)");
}
