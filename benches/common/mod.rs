//! Shared bench configuration.
//!
//! Benches default to a reduced window (40 s profile + 40 s eval) so the
//! whole suite finishes in minutes; set `CROSSROI_FULL=1` for the paper's
//! full 60 s + 120 s windows.

use crossroi::config::Config;

/// The scenario/system configuration all benches run against.
pub fn bench_config() -> Config {
    let mut cfg = Config::paper();
    if std::env::var("CROSSROI_FULL").ok().as_deref() != Some("1") {
        cfg.scenario.profile_secs = 40.0;
        cfg.scenario.eval_secs = 40.0;
    }
    cfg
}

/// A shorter eval for parameter sweeps (figs 9-11).
pub fn sweep_config() -> Config {
    let mut cfg = bench_config();
    if std::env::var("CROSSROI_FULL").ok().as_deref() != Some("1") {
        cfg.scenario.eval_secs = 25.0;
    }
    cfg
}

/// Load the PJRT runtime or exit with a hint (pjrt-feature benches only).
#[cfg(feature = "pjrt")]
pub fn load_runtime(cfg: &Config) -> crossroi::runtime::Runtime {
    match crossroi::runtime::Runtime::load(&cfg.system.artifacts_dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("cannot load artifacts: {e:#}\nrun `make artifacts` first");
            std::process::exit(1);
        }
    }
}
