//! Hot-path scoreboard for the §Perf pass (EXPERIMENTS.md): per-kernel
//! scalar-vs-SIMD timings (DCT, quantize, SAD, entropy, u8→f32 convert),
//! whole-encoder segment throughput, renderer, DES, detector
//! post-processing, and (with `--features pjrt`) the real PJRT
//! executables.  Every encoder is constructed OUTSIDE the timed closure —
//! `encode_segment` resets its GOPs internally, so the timed region is
//! pure encode work, not setup.
//!
//! Besides the printed table the bench writes `BENCH_hotpath.json`
//! (machine-readable rows: name, scalar_secs, simd_secs, speedup, iters,
//! notes) so CI can archive the scoreboard per commit.
//!
//! Run: `cargo bench --bench perf_hotpath`
//! Quick smoke (CI): `CROSSROI_BENCH_QUICK=1 cargo bench --bench perf_hotpath`

use crossroi::bench::{time_it, Table, Timing};
use crossroi::codec::encoder::Planes;
use crossroi::codec::{
    avx2_supported, backend, dct, entropy, motion, set_backend, KernelBackend, SegmentEncoder,
};
use crossroi::config::Config;
use crossroi::net::Des;
use crossroi::runtime::decode_objectness;
use crossroi::sim::Scenario;
use crossroi::util::geometry::IRect;
use crossroi::util::json::Json;

/// One scoreboard row: a component timed under the scalar backend and —
/// when the host supports it — under the AVX2 backend.
struct Row {
    name: String,
    scalar: Timing,
    simd: Option<Timing>,
    notes: String,
}

/// Iteration plan: (warmup, iters, target_secs), shrunk to a smoke run
/// when `CROSSROI_BENCH_QUICK=1` (the CI leg only checks the bench runs
/// end to end and emits well-formed JSON).
struct Plan {
    quick: bool,
}

impl Plan {
    fn params(&self, warmup: usize, iters: usize, secs: f64) -> (usize, usize, f64) {
        if self.quick {
            (1, 3, 1.0)
        } else {
            (warmup, iters, secs)
        }
    }

    /// Time `f` under the forced scalar backend, then (if supported) the
    /// forced AVX2 backend; always restores auto-detection.  Safe to flip
    /// mid-process because the two backends are byte-identical — state
    /// carried across calls (encoder references, buffers) is unaffected.
    fn pair<F: FnMut()>(
        &self,
        warmup: usize,
        iters: usize,
        secs: f64,
        mut f: F,
    ) -> (Timing, Option<Timing>) {
        let (w, i, s) = self.params(warmup, iters, secs);
        set_backend(Some(KernelBackend::Scalar));
        let scalar = time_it(w, i, s, &mut f);
        let simd = if avx2_supported() {
            set_backend(Some(KernelBackend::Avx2));
            Some(time_it(w, i, s, &mut f))
        } else {
            None
        };
        set_backend(None);
        (scalar, simd)
    }

    fn single<F: FnMut()>(&self, warmup: usize, iters: usize, secs: f64, f: F) -> Timing {
        let (w, i, s) = self.params(warmup, iters, secs);
        time_it(w, i, s, f)
    }
}

/// Deterministic pseudo-random DCT input blocks (codec-like magnitudes).
fn sample_blocks(n: usize) -> Vec<[f32; 64]> {
    let mut state = 0x9e3779b97f4a7c15u64;
    (0..n)
        .map(|_| {
            let mut b = [0.0f32; 64];
            for v in b.iter_mut() {
                state = state
                    .wrapping_mul(6364136223846793005)
                    .wrapping_add(1442695040888963407);
                *v = ((state >> 40) as f32 / (1u64 << 24) as f32 - 0.5) * 400.0;
            }
            b
        })
        .collect()
}

fn main() {
    let plan = Plan {
        quick: std::env::var("CROSSROI_BENCH_QUICK").ok().as_deref() == Some("1"),
    };
    let cfg = Config::test_small();
    let scenario = Scenario::build(&cfg.scenario);
    let renderer = scenario.renderer();
    let mut rows: Vec<Row> = Vec::new();

    // renderer (no kernel dispatch on this path)
    let t = plan.single(3, 50, 5.0, || {
        std::hint::black_box(renderer.render(0, 10));
    });
    rows.push(Row {
        name: "render frame".into(),
        scalar: t,
        simd: None,
        notes: "320x192 background+vehicles+noise".into(),
    });

    // ---- per-kernel scalar vs SIMD ----

    let blocks = sample_blocks(256);
    let (scalar, simd) = plan.pair(3, 50, 5.0, || {
        for b in &blocks {
            let mut fwd = *b;
            dct::forward(&mut fwd);
            dct::inverse(&mut fwd);
            std::hint::black_box(&fwd);
        }
    });
    rows.push(Row {
        name: "dct forward+inverse".into(),
        scalar,
        simd,
        notes: "256 8x8 blocks".into(),
    });

    let coeffs: Vec<[f32; 64]> = blocks
        .iter()
        .map(|b| {
            let mut c = *b;
            dct::forward(&mut c);
            c
        })
        .collect();
    let (scalar, simd) = plan.pair(3, 50, 5.0, || {
        for c in &coeffs {
            let q = dct::quantize(c, 6.0);
            std::hint::black_box(dct::dequantize(&q, 6.0));
        }
    });
    rows.push(Row {
        name: "quantize+dequantize".into(),
        scalar,
        simd,
        notes: "256 blocks, qp 6".into(),
    });

    let full = IRect::new(0, 0, 320, 192);
    let plane_a = Planes::from_frame_region(&renderer.render(0, 0), full);
    let plane_b = Planes::from_frame_region(&renderer.render(0, 1), full);
    let pa = motion::Plane { w: plane_a.w, h: plane_a.h, data: &plane_a.y };
    let pb = motion::Plane { w: plane_b.w, h: plane_b.h, data: &plane_b.y };
    let n_mbs = (pa.w / 16) * (pa.h / 16);
    let (scalar, simd) = plan.pair(3, 50, 5.0, || {
        for by in (0..pa.h).step_by(16) {
            for bx in (0..pa.w).step_by(16) {
                std::hint::black_box(motion::sad(&pb, &pa, bx, by, 1, 1, f32::INFINITY));
            }
        }
    });
    rows.push(Row {
        name: "motion SAD".into(),
        scalar,
        simd,
        notes: format!("{n_mbs} MBs, (1,1) displacement"),
    });

    let levels: Vec<[i32; 64]> = coeffs.iter().map(|c| dct::quantize(c, 6.0)).collect();
    let (scalar, simd) = plan.pair(3, 200, 5.0, || {
        let mut prev_dc = 0i32;
        for l in &levels {
            let (bits, dc) = entropy::block_bits(l, prev_dc);
            prev_dc = dc;
            std::hint::black_box(bits);
        }
    });
    rows.push(Row {
        name: "entropy block_bits".into(),
        scalar,
        simd,
        notes: "256 blocks, zig-zag+RLE cost".into(),
    });

    let frame = renderer.render(0, 10);
    let roi = [IRect::new(64, 48, 160, 96)];
    let mut masked_buf: Vec<f32> = Vec::new();
    let (scalar, simd) = plan.pair(3, 200, 5.0, || {
        frame.masked_f32_into(&roi, &mut masked_buf);
        std::hint::black_box(&masked_buf);
    });
    rows.push(Row {
        name: "masked u8->f32 convert".into(),
        scalar,
        simd,
        notes: "25% RoI, reused buffer".into(),
    });

    // ---- whole-encoder throughput (all kernels in concert) ----

    let frames: Vec<_> = (0..10).map(|i| renderer.render(0, i)).collect();
    let mut enc_full = SegmentEncoder::new(&[full], 6.0);
    let (scalar, simd) = plan.pair(1, 20, 10.0, || {
        std::hint::black_box(enc_full.encode_segment(&frames));
    });
    let fps = 10.0 / simd.as_ref().unwrap_or(&scalar).mean_secs;
    rows.push(Row {
        name: "encode 10-frame segment (full)".into(),
        scalar,
        simd,
        notes: format!("{fps:.1} fps best"),
    });

    let mut enc_roi = SegmentEncoder::new(&[IRect::new(64, 48, 160, 96)], 6.0);
    let (scalar, simd) = plan.pair(1, 20, 10.0, || {
        std::hint::black_box(enc_roi.encode_segment(&frames));
    });
    let fps = 10.0 / simd.as_ref().unwrap_or(&scalar).mean_secs;
    rows.push(Row {
        name: "encode 10-frame segment (25% RoI)".into(),
        scalar,
        simd,
        notes: format!("{fps:.1} fps best"),
    });

    // ---- non-kernel hot paths ----

    let t = plan.single(1, 10, 5.0, || {
        let mut des: Des<u64> = Des::new();
        for i in 0..10_000 {
            des.at(i as f64 * 0.001, i);
        }
        while let Some((_, e)) = des.pop() {
            std::hint::black_box(e);
        }
    });
    rows.push(Row {
        name: "DES 10k events".into(),
        scalar: t,
        simd: None,
        notes: "schedule + drain".into(),
    });

    let grid: Vec<f32> = (0..240).map(|i| if i % 7 == 0 { 0.8 } else { 0.0 }).collect();
    let t = plan.single(10, 1000, 2.0, || {
        std::hint::black_box(decode_objectness(&grid, 12, 20, 16, 0.25));
    });
    rows.push(Row {
        name: "postproc decode".into(),
        scalar: t,
        simd: None,
        notes: "12x20 grid".into(),
    });

    // ---- PJRT executables (feature-gated; skipped without artifacts) ----
    #[cfg(feature = "pjrt")]
    match crossroi::runtime::Runtime::load("artifacts") {
        Err(e) => println!("(skipping PJRT benches: {e:#})"),
        Ok(rt) => {
            let f32_frame = renderer.render(0, 10).to_f32();
            let t = plan.single(3, 50, 10.0, || {
                std::hint::black_box(rt.infer_full(&f32_frame).unwrap());
            });
            rows.push(Row {
                name: "HLO dense detector".into(),
                scalar: t,
                simd: None,
                notes: format!("{:.1} Hz", 1.0 / t.mean_secs),
            });
            for &k in &[8usize, 16, 32, 60] {
                let blocks: Vec<i32> = (0..k as i32).collect();
                let t = plan.single(3, 50, 10.0, || {
                    std::hint::black_box(rt.infer_roi(&f32_frame, &blocks).unwrap());
                });
                rows.push(Row {
                    name: format!("HLO RoI detector K={k}"),
                    scalar: t,
                    simd: None,
                    notes: format!("{:.1} Hz, {k} active blocks", 1.0 / t.mean_secs),
                });
            }
        }
    }

    // ---- table + machine-readable scoreboard ----

    let mut table = Table::new(&["component", "scalar", "simd", "speedup", "iters", "notes"]);
    for r in &rows {
        let (simd_col, speedup_col) = match &r.simd {
            Some(s) => (
                s.per_iter_display(),
                format!("{:.2}x", r.scalar.mean_secs / s.mean_secs),
            ),
            None => ("-".into(), "-".into()),
        };
        table.row(vec![
            r.name.clone(),
            r.scalar.per_iter_display(),
            simd_col,
            speedup_col,
            r.scalar.iters.to_string(),
            r.notes.clone(),
        ]);
    }
    table.print("perf_hotpath — scalar vs SIMD per-component timings");
    println!(
        "kernel backend: default {} (avx2 supported: {})",
        backend().name(),
        avx2_supported()
    );

    let json_rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("name", Json::Str(r.name.clone())),
                ("scalar_secs", Json::Num(r.scalar.mean_secs)),
                (
                    "simd_secs",
                    r.simd.as_ref().map_or(Json::Null, |s| Json::Num(s.mean_secs)),
                ),
                (
                    "speedup",
                    r.simd
                        .as_ref()
                        .map_or(Json::Null, |s| Json::Num(r.scalar.mean_secs / s.mean_secs)),
                ),
                ("iters", Json::Num(r.scalar.iters as f64)),
                ("notes", Json::Str(r.notes.clone())),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::Str("perf_hotpath".into())),
        ("avx2_supported", Json::Bool(avx2_supported())),
        ("backend_default", Json::Str(backend().name().into())),
        ("quick", Json::Bool(plan.quick)),
        ("rows", Json::Arr(json_rows)),
    ]);
    let path = "BENCH_hotpath.json";
    std::fs::write(path, doc.to_string_pretty(2) + "\n").expect("write scoreboard");
    println!("scoreboard written to {path}");
}
