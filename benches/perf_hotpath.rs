//! Hot-path microbenchmarks for the §Perf pass (EXPERIMENTS.md):
//! L3 codec encode, renderer, DES, detector post-processing, and the real
//! PJRT executables (dense + every RoI capacity).
//!
//! Run: `cargo bench --bench perf_hotpath`

use crossroi::bench::{time_it, Table};
use crossroi::codec::SegmentEncoder;
use crossroi::config::Config;
use crossroi::net::Des;
use crossroi::runtime::{decode_objectness, Runtime};
use crossroi::sim::Scenario;
use crossroi::util::geometry::IRect;

fn main() {
    let cfg = Config::test_small();
    let scenario = Scenario::build(&cfg.scenario);
    let renderer = scenario.renderer();
    let mut table = Table::new(&["component", "per-iter", "iters", "notes"]);

    // renderer
    let t = time_it(3, 50, 5.0, || {
        std::hint::black_box(renderer.render(0, 10));
    });
    table.row(vec![
        "render frame".into(),
        t.per_iter_display(),
        t.iters.to_string(),
        "320x192 background+vehicles+noise".into(),
    ]);

    // codec: full-frame segment (10 frames)
    let frames: Vec<_> = (0..10).map(|i| renderer.render(0, i)).collect();
    let t = time_it(1, 20, 10.0, || {
        let mut enc = SegmentEncoder::new(&[IRect::new(0, 0, 320, 192)], 6.0);
        std::hint::black_box(enc.encode_segment(&frames));
    });
    table.row(vec![
        "encode 10-frame segment (full)".into(),
        t.per_iter_display(),
        t.iters.to_string(),
        format!("{:.1} fps", 10.0 / t.mean_secs),
    ]);

    // codec: quarter-frame RoI
    let t = time_it(1, 20, 10.0, || {
        let mut enc = SegmentEncoder::new(&[IRect::new(64, 48, 160, 96)], 6.0);
        std::hint::black_box(enc.encode_segment(&frames));
    });
    table.row(vec![
        "encode 10-frame segment (25% RoI)".into(),
        t.per_iter_display(),
        t.iters.to_string(),
        format!("{:.1} fps", 10.0 / t.mean_secs),
    ]);

    // DES throughput
    let t = time_it(1, 10, 5.0, || {
        let mut des: Des<u64> = Des::new();
        for i in 0..10_000 {
            des.at(i as f64 * 0.001, i);
        }
        while let Some((_, e)) = des.pop() {
            std::hint::black_box(e);
        }
    });
    table.row(vec![
        "DES 10k events".into(),
        t.per_iter_display(),
        t.iters.to_string(),
        format!("{:.1} M events/s", 10_000.0 / t.mean_secs / 1e6),
    ]);

    // postproc
    let grid: Vec<f32> = (0..240).map(|i| if i % 7 == 0 { 0.8 } else { 0.0 }).collect();
    let t = time_it(10, 1000, 2.0, || {
        std::hint::black_box(decode_objectness(&grid, 12, 20, 16, 0.25));
    });
    table.row(vec![
        "postproc decode".into(),
        t.per_iter_display(),
        t.iters.to_string(),
        "12x20 grid".into(),
    ]);

    // PJRT executables (skipped when artifacts are absent)
    match Runtime::load("artifacts") {
        Err(e) => println!("(skipping PJRT benches: {e:#})"),
        Ok(rt) => {
            let frame = renderer.render(0, 10).to_f32();
            let t = time_it(3, 50, 10.0, || {
                std::hint::black_box(rt.infer_full(&frame).unwrap());
            });
            table.row(vec![
                "HLO dense detector".into(),
                t.per_iter_display(),
                t.iters.to_string(),
                format!("{:.1} Hz", 1.0 / t.mean_secs),
            ]);
            for &k in &[8usize, 16, 32, 60] {
                let blocks: Vec<i32> = (0..k as i32).collect();
                let t = time_it(3, 50, 10.0, || {
                    std::hint::black_box(rt.infer_roi(&frame, &blocks).unwrap());
                });
                table.row(vec![
                    format!("HLO RoI detector K={k}"),
                    t.per_iter_display(),
                    t.iters.to_string(),
                    format!("{:.1} Hz, {} active blocks", 1.0 / t.mean_secs, k),
                ]);
            }
        }
    }

    table.print("perf_hotpath — per-component timings");
}
