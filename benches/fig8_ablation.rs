//! Figure 8 — full ablation: Baseline / No-Filters / No-Merging /
//! No-RoIInf / CrossRoI over the online window, reporting all four §5.1.2
//! metrics plus the Fig. 8b missed-vehicle distribution.
//!
//! Expected shape (paper): CrossRoI least network (−42 % vs Baseline) and
//! least latency (−25 %), highest server Hz and camera fps, accuracy
//! ≥ 99 %; No-Filters slightly worse network than CrossRoI; No-Merging
//! worse network than CrossRoI; No-RoIInf lower server Hz than CrossRoI.

mod common;

use crossroi::bench::{fmt, Table};
use crossroi::coordinator::{run_ablation, Method, RuntimeInfer};
use crossroi::sim::Scenario;

fn main() {
    let cfg = common::bench_config();
    let scenario = Scenario::build(&cfg.scenario);
    let rt = common::load_runtime(&cfg);
    let infer = RuntimeInfer(&rt);
    let methods = [
        Method::Baseline,
        Method::NoFilters,
        Method::NoMerging,
        Method::NoRoiInf,
        Method::CrossRoi,
    ];
    println!(
        "eval window: {:.0} s x {} cams @ {} fps, segment {} s, link {} Mbps",
        cfg.scenario.eval_secs,
        cfg.scenario.n_cameras,
        cfg.scenario.fps,
        cfg.system.segment_secs,
        cfg.system.bandwidth_mbps
    );
    let reports = run_ablation(&scenario, &cfg.system, &infer, &methods).unwrap();

    // fig 8a/c/d/e/f summary
    let mut table = Table::new(&[
        "method", "accuracy", "net Mbps", "srv Hz", "cam fps", "e2e s", "cam s", "net s",
        "srv s", "|M| tiles",
    ]);
    for r in &reports {
        table.row(vec![
            r.method.clone(),
            fmt(r.accuracy, 4),
            fmt(r.network_mbps_total, 2),
            fmt(r.server_hz, 1),
            fmt(r.camera_fps, 1),
            fmt(r.latency.total(), 3),
            fmt(r.latency.camera, 3),
            fmt(r.latency.network, 3),
            fmt(r.latency.server, 3),
            r.mask_tiles.to_string(),
        ]);
    }
    table.print("Fig. 8 (a,c,d,e,f) — ablation summary");

    // fig 8c per-camera network bars
    let mut net = Table::new(&["method", "C1", "C2", "C3", "C4", "C5", "total"]);
    for r in &reports {
        let mut row = vec![r.method.clone()];
        for c in 0..5 {
            row.push(fmt(r.network_mbps_per_cam.get(c).copied().unwrap_or(0.0), 3));
        }
        row.push(fmt(r.network_mbps_total, 3));
        net.row(row);
    }
    net.print("Fig. 8c — per-camera network overhead (Mbps)");

    // fig 8b missed-vehicle distribution for CrossRoI
    if let Some(cross) = reports.iter().find(|r| r.method == "CrossRoI") {
        let max_missed = cross.missed_per_frame.iter().copied().max().unwrap_or(0);
        let mut hist = Table::new(&["missed vehicles", "#frames"]);
        for k in 0..=max_missed {
            let count = cross.missed_per_frame.iter().filter(|&&m| m == k).count();
            hist.row(vec![k.to_string(), count.to_string()]);
        }
        hist.print("Fig. 8b — CrossRoI missed-vehicle distribution per timestamp");
        println!(
            "\nCrossRoI: {} total appearances in reference window",
            cross.total_appearances
        );
    }

    // shape assertions printed for EXPERIMENTS.md
    let get = |name: &str| reports.iter().find(|r| r.method == name).unwrap();
    let base = get("Baseline");
    let cross = get("CrossRoI");
    println!("\nshape checks:");
    println!(
        "  network reduction vs Baseline: {:.0}% (paper 42-65%)",
        100.0 * (1.0 - cross.network_mbps_total / base.network_mbps_total)
    );
    println!(
        "  latency reduction vs Baseline: {:.0}% (paper 25-34%)",
        100.0 * (1.0 - cross.latency.total() / base.latency.total())
    );
    println!(
        "  server speedup vs No-RoIInf: {:.2}x (paper ~1.18x)",
        cross.server_hz / get("No-RoIInf").server_hz
    );
    println!(
        "  net: CrossRoI {} < No-Filters {} ; CrossRoI {} < No-Merging {}",
        fmt(cross.network_mbps_total, 2),
        fmt(get("No-Filters").network_mbps_total, 2),
        fmt(cross.network_mbps_total, 2),
        fmt(get("No-Merging").network_mbps_total, 2),
    );
    println!("  accuracy: CrossRoI {:.4} (paper 0.999)", cross.accuracy);
}
