//! Online-phase scaling — the DES-replayed streaming pipeline from 4 to
//! 16 cameras on one shared uplink, the online-side counterpart of
//! `benches/offline_scaling.rs`:
//!
//! 1. **Shared-link contention sweep** (4→16 cameras, Baseline vs
//!    CrossRoI): aggregate bitrate, link-queueing latency and the
//!    end-to-end decomposition as the fleet outgrows the link.  The
//!    paper's claim at fleet scale: Baseline saturates the uplink first,
//!    CrossRoI's masks keep the same fleet under the knee.
//! 2. **Component re-planning sweep** (disjoint intersections, drift in
//!    exactly one): per-epoch re-plan cost under `--replan-scope
//!    component` vs `fleet` at growing fleet sizes.  The component-scoped
//!    epoch filters and re-solves only the drifted intersection, so its
//!    cost should track the *component* size while the fleet-scoped
//!    epoch pays for the whole fleet — with a noise-tolerant backstop
//!    assert (component ≤ 1.25 × fleet) so a regression fails the bench.
//!
//! Runs uncontended (`Parallelism::Sequential`) with the native detector
//! so the measured service times are comparable across fleet sizes.

mod common;

use std::sync::Arc;

use crossroi::bench::Table;
use crossroi::config::Config;
use crossroi::coordinator::{run_method_with, Method};
use crossroi::offline::{build_plan, OfflineOptions, Replanner};
use crossroi::pipeline::{
    EncodeCost, EpochPlanner as _, NativeInfer, Parallelism, PipelineOptions, PlanEpoch,
    ReplanPolicy, ReplanScope,
};
use crossroi::sim::Scenario;

fn link_sweep(base: &Config) {
    let mut table = Table::new(&[
        "cams",
        "method",
        "net Mbps",
        "bytes",
        "cam fps",
        "e2e s",
        "net lat s",
        "p95 s",
    ]);
    let opts = PipelineOptions {
        parallelism: Parallelism::Sequential,
        encode_cost: EncodeCost::Measured,
        ..PipelineOptions::default()
    };
    for cams in [4usize, 8, 16] {
        let mut cfg = base.clone();
        cfg.scenario.n_cameras = cams;
        // keep the bench quick: the contention story is per-segment
        cfg.scenario.profile_secs = 10.0;
        cfg.scenario.eval_secs = 10.0;
        let scenario = Scenario::build(&cfg.scenario);
        for method in [Method::Baseline, Method::CrossRoi] {
            let (report, _) = run_method_with(
                &scenario,
                &cfg.system,
                &NativeInfer,
                &method,
                None,
                &opts,
            )
            .unwrap();
            table.row(vec![
                format!("{cams}"),
                report.method.clone(),
                format!("{:.2}", report.network_mbps_total),
                format!("{}", report.bytes_total),
                format!("{:.1}", report.camera_fps),
                format!("{:.3}", report.latency.total()),
                format!("{:.3}", report.latency.network),
                format!("{:.3}", report.latency_p95),
            ]);
        }
    }
    table.print("Online scaling (shared 1.8 Mbps uplink, 4-16 cameras, sequential measurement)");
}

fn replan_scope_sweep(base: &Config) {
    let mut table = Table::new(&[
        "intersections",
        "cams",
        "drift comp",
        "fired/total",
        "component ms",
        "fleet ms",
        "speedup",
    ]);
    for n_intersections in [2usize, 3, 4] {
        let mut cfg = base.clone();
        cfg.scenario.n_cameras = 4;
        cfg.scenario.n_intersections = n_intersections;
        cfg.scenario.profile_secs = 10.0;
        cfg.scenario.eval_secs = 10.0;
        // drift exactly one intersection mid-eval; the others stay put
        cfg.scenario.drift_at_secs = 12.0;
        cfg.scenario.drift_strength = 0.9;
        cfg.scenario.drift_intersection = 0;
        cfg.scenario.validate().unwrap();
        let scenario = Scenario::build(&cfg.scenario);
        let method = Method::CrossRoi;
        let plan = build_plan(&scenario, &cfg.scenario, &cfg.system, &method).unwrap();
        let n_cams = scenario.cameras.len();
        let epoch0 = Arc::new(PlanEpoch::initial(
            plan.groups.clone(),
            plan.blocks.clone(),
            vec![true; n_cams],
            None,
            plan.masks.total_size(),
        ));
        // one post-drift boundary, re-planned under each scope.  The
        // drift policy gates on a threshold between the quiescent noise
        // floor and the drifted component's signal, measured first.
        let measure = Replanner::new(
            &scenario,
            &cfg.system,
            &method,
            OfflineOptions::default(),
            ReplanPolicy::Every(2),
            ReplanScope::Component,
            5,
            &plan,
            60,
        );
        measure.plan_epoch(1, 8, &epoch0).unwrap();
        let records = measure.records();
        let drifts: Vec<f64> = records[0].components.iter().map(|c| c.drift).collect();
        let hot = drifts.iter().cloned().fold(f64::MIN, f64::max);
        let calm = drifts.iter().cloned().fold(f64::MAX, f64::min);
        let threshold = (hot + calm) / 2.0;
        let time_epoch = |policy: ReplanPolicy, scope: ReplanScope| -> (f64, usize, usize) {
            let rp = Replanner::new(
                &scenario,
                &cfg.system,
                &method,
                OfflineOptions::default(),
                policy,
                scope,
                5,
                &plan,
                60,
            );
            rp.plan_epoch(1, 8, &epoch0).unwrap();
            let recs = rp.records();
            (recs[0].seconds, recs[0].fired_components(), recs[0].components.len())
        };
        // component scope gates on the drift threshold (only the drifted
        // intersection fires); the fleet-scoped reference re-plans the
        // whole fleet as one instance — what every epoch cost before
        // component-incremental re-planning
        let (comp_s, comp_fired, comp_total) = time_epoch(
            ReplanPolicy::Drift { check_every: 2, threshold },
            ReplanScope::Component,
        );
        let (fleet_s, _, _) = time_epoch(ReplanPolicy::Every(2), ReplanScope::Fleet);
        // the per-epoch cost must track the drifted component, not the
        // fleet; the backstop only trips on a real regression
        assert!(
            comp_s <= fleet_s * 1.25,
            "component-scoped epoch ({comp_s:.4}s) regressed past fleet-scoped \
             ({fleet_s:.4}s) at {n_intersections} intersections"
        );
        table.row(vec![
            format!("{n_intersections}"),
            format!("{n_cams}"),
            format!("{hot:.2}"),
            format!("{comp_fired}/{comp_total}"),
            format!("{:.1}", comp_s * 1e3),
            format!("{:.1}", fleet_s * 1e3),
            format!("{:.2}x", fleet_s / comp_s.max(1e-9)),
        ]);
    }
    table.print(
        "Component-incremental re-planning (single-intersection drift; per-epoch cost, \
         component vs fleet scope)",
    );
}

fn main() {
    let base = common::bench_config();
    link_sweep(&base);
    replan_scope_sweep(&base);
}
