//! Planner-pool scaling — per-epoch re-plan latency as the fleet grows
//! from 1×4 to 8×32 (intersections × total cameras), with the epoch's
//! compute phase fanned out over 1 vs 4 pool workers
//! (`--planner-threads`).
//!
//! Every intersection drifts mid-eval and the policy is `Every`, so a
//! measured epoch re-solves *all* of its components — the worst case the
//! pool exists for.  Per-epoch wall latency is recorded as p50/p99 per
//! thread count, plus the pool speedup (sequential p50 / pooled p50).
//! The final epochs of both pool sizes are asserted identical — the
//! snapshot/compute/commit phases must not let the thread count leak
//! into the plan (the full byte-identity gate lives in
//! `rust/tests/component_replan.rs`).
//!
//! Besides the printed table the bench writes `BENCH_replan.json`.
//!
//! Quick smoke (CI): `CROSSROI_BENCH_QUICK=1 cargo bench --bench replan_scaling`

use std::sync::Arc;
use std::time::Instant;

use crossroi::bench::Table;
use crossroi::config::Config;
use crossroi::coordinator::Method;
use crossroi::offline::{build_plan, OfflineOptions, Replanner};
use crossroi::pipeline::{EpochPlanner as _, PlanEpoch, ReplanPolicy, ReplanScope};
use crossroi::sim::Scenario;
use crossroi::util::json::Json;
use crossroi::util::stats::percentile;

/// Epoch latencies for one (fleet size, thread count) cell, plus the
/// final plan the identity check compares across thread counts.
struct Cell {
    p50_ms: f64,
    p99_ms: f64,
    fired: usize,
    components: usize,
    final_epoch: Arc<PlanEpoch>,
}

fn time_epochs(
    scenario: &Scenario,
    cfg: &Config,
    plan: &crossroi::offline::OfflinePlan,
    epoch0: &Arc<PlanEpoch>,
    threads: usize,
    iters: usize,
) -> Cell {
    let method = Method::CrossRoi;
    let rp = Replanner::new(
        scenario,
        &cfg.system,
        &method,
        OfflineOptions::default(),
        ReplanPolicy::Every(2),
        ReplanScope::Component,
        5,
        plan,
        60,
    )
    .with_planner_threads(threads);
    // warm-up epoch (pre-drift boundary): pays the one-time drift-baseline
    // derivation so the timed epochs measure steady-state re-plans only
    let mut prev = rp.plan_epoch(1, 4, epoch0).expect("warm-up epoch");
    // timed epochs at a fixed post-drift boundary: the window is the same
    // every iteration, so each epoch re-solves the same fired instance
    let mut lat: Vec<f64> = Vec::with_capacity(iters);
    for it in 0..iters {
        let t0 = Instant::now();
        prev = rp.plan_epoch(2 + it, 8, &prev).expect("timed epoch");
        lat.push(t0.elapsed().as_secs_f64());
    }
    let records = rp.records();
    let last = records.last().expect("timed epochs recorded");
    assert!(last.replanned, "an Every-policy post-drift epoch must fire");
    let stats = rp.pool_stats();
    assert_eq!(stats.epochs_computed, 1 + iters);
    assert!(stats.max_concurrent >= 1);
    Cell {
        p50_ms: percentile(&lat, 50.0) * 1e3,
        p99_ms: percentile(&lat, 99.0) * 1e3,
        fired: last.fired_components(),
        components: last.components.len(),
        final_epoch: prev,
    }
}

fn main() {
    let quick = std::env::var("CROSSROI_BENCH_QUICK").ok().as_deref() == Some("1");
    let sweep: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4, 8] };
    let iters = if quick { 2 } else { 6 };

    let mut table = Table::new(&[
        "intersections",
        "cams",
        "fired/total",
        "p50 1t ms",
        "p99 1t ms",
        "p50 4t ms",
        "p99 4t ms",
        "speedup",
    ]);
    let mut rows: Vec<Json> = Vec::new();
    for &n_intersections in sweep {
        let mut cfg = Config::paper();
        cfg.scenario.n_cameras = 4;
        cfg.scenario.n_intersections = n_intersections;
        cfg.scenario.profile_secs = 10.0;
        cfg.scenario.eval_secs = 10.0;
        // every intersection drifts: the measured epochs re-solve the
        // whole fleet, component by component, on the pool
        cfg.scenario.drift_at_secs = 12.0;
        cfg.scenario.drift_strength = 0.9;
        cfg.scenario.drift_intersection = -1;
        cfg.scenario.validate().unwrap();
        let scenario = Scenario::build(&cfg.scenario);
        let method = Method::CrossRoi;
        let plan = build_plan(&scenario, &cfg.scenario, &cfg.system, &method).unwrap();
        let n_cams = scenario.cameras.len();
        let epoch0 = Arc::new(PlanEpoch::initial(
            plan.groups.clone(),
            plan.blocks.clone(),
            vec![true; n_cams],
            None,
            plan.masks.total_size(),
        ));

        let seq = time_epochs(&scenario, &cfg, &plan, &epoch0, 1, iters);
        let pool = time_epochs(&scenario, &cfg, &plan, &epoch0, 4, iters);
        // the thread count must not leak into the plan
        assert_eq!(
            seq.final_epoch.groups, pool.final_epoch.groups,
            "pooled re-plan diverged from sequential at {n_intersections} intersections"
        );
        assert_eq!(seq.final_epoch.mask_tiles, pool.final_epoch.mask_tiles);
        assert_eq!((seq.fired, seq.components), (pool.fired, pool.components));

        let speedup = seq.p50_ms / pool.p50_ms.max(1e-9);
        table.row(vec![
            format!("{n_intersections}"),
            format!("{n_cams}"),
            format!("{}/{}", pool.fired, pool.components),
            format!("{:.1}", seq.p50_ms),
            format!("{:.1}", seq.p99_ms),
            format!("{:.1}", pool.p50_ms),
            format!("{:.1}", pool.p99_ms),
            format!("{speedup:.2}x"),
        ]);
        rows.push(Json::obj(vec![
            ("intersections", Json::Num(n_intersections as f64)),
            ("cameras", Json::Num(n_cams as f64)),
            ("fired_components", Json::Num(pool.fired as f64)),
            ("components", Json::Num(pool.components as f64)),
            ("p50_ms_1t", Json::Num(seq.p50_ms)),
            ("p99_ms_1t", Json::Num(seq.p99_ms)),
            ("p50_ms_4t", Json::Num(pool.p50_ms)),
            ("p99_ms_4t", Json::Num(pool.p99_ms)),
            ("speedup_4t", Json::Num(speedup)),
        ]));
    }
    table.print(
        "Per-epoch re-plan latency, planner pool 1 vs 4 workers (all intersections drifted)",
    );

    let doc = Json::obj(vec![
        ("bench", Json::Str("replan_scaling".into())),
        ("quick", Json::Bool(quick)),
        ("iters_per_cell", Json::Num(iters as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    let path = "BENCH_replan.json";
    std::fs::write(path, doc.to_string_pretty(2) + "\n").expect("write scoreboard");
    println!("wrote {path}");
}
