//! Offline planner scaling — camera-count sweep for the staged planner:
//! per-stage seconds and the multi-thread speedup of the O(n²) pair
//! fitting (ReXCam's argument: cross-camera correlation profiling is the
//! city-scale bottleneck; this tracks how far the parallel planner pushes
//! it).
//!
//! Expected shape: the filter stage dominates and grows ~quadratically
//! with cameras; with one worker per core the filter stage — and at 8+
//! cameras the whole offline phase — should clear a ≥ 3× speedup over
//! `--offline-threads 1`, while the plans stay byte-identical
//! (`rust/tests/offline_determinism.rs` proves the identity; this bench
//! spot-checks |M|).

mod common;

use crossroi::bench::Table;
use crossroi::coordinator::Method;
use crossroi::offline::{build_plan_with, OfflineOptions, OfflinePlan, SolverKind};
use crossroi::sim::Scenario;

fn stage(plan: &OfflinePlan, name: &str) -> f64 {
    plan.report.stage_seconds(name).unwrap_or(0.0)
}

fn main() {
    let base = common::bench_config();
    let threads = OfflineOptions::default().effective_threads();
    println!(
        "offline scaling sweep: {}s profile window, {} worker threads (auto)",
        base.scenario.profile_secs, threads
    );

    let mut table = Table::new(&[
        "cams",
        "constraints",
        "profile s",
        "filter s (1t)",
        "filter s (auto)",
        "solve s",
        "total s (1t)",
        "total s (auto)",
        "speedup",
    ]);
    for cams in [4usize, 8, 12, 16] {
        let mut cfg = base.clone();
        cfg.scenario.n_cameras = cams;
        let scenario = Scenario::build(&cfg.scenario);
        let sequential = build_plan_with(
            &scenario,
            &cfg.scenario,
            &cfg.system,
            &Method::CrossRoi,
            &OfflineOptions { threads: 1, solver: SolverKind::Greedy },
        )
        .unwrap();
        let parallel = build_plan_with(
            &scenario,
            &cfg.scenario,
            &cfg.system,
            &Method::CrossRoi,
            &OfflineOptions { threads: 0, solver: SolverKind::Greedy },
        )
        .unwrap();
        assert_eq!(
            sequential.masks.total_size(),
            parallel.masks.total_size(),
            "parallel plan diverged from sequential at {cams} cameras"
        );
        table.row(vec![
            format!("{cams}"),
            format!("{}", parallel.n_constraints),
            format!("{:.3}", stage(&parallel, "profile")),
            format!("{:.3}", stage(&sequential, "filter")),
            format!("{:.3}", stage(&parallel, "filter")),
            format!("{:.3}", stage(&parallel, "solve")),
            format!("{:.3}", sequential.seconds()),
            format!("{:.3}", parallel.seconds()),
            format!("{:.2}x", sequential.seconds() / parallel.seconds().max(1e-9)),
        ]);
    }
    table.print("Offline planner scaling (camera sweep, CrossRoI method)");
}
