//! Offline planner scaling — two sweeps for the staged planner:
//!
//! 1. **Single intersection, camera sweep** (4→16 cameras): per-stage
//!    seconds and the multi-thread speedup of the O(n²) pair fitting
//!    (ReXCam's argument: cross-camera correlation profiling is the
//!    city-scale bottleneck; this tracks how far the parallel planner
//!    pushes it).
//! 2. **Disjoint intersections, fleet sweep** (16→64 cameras as 4-camera
//!    intersections): overlap-sharded planning (`--shards auto`) against
//!    the single-instance planner on the same fleet.  The co-occurrence
//!    partition recovers the intersections, every shard plans
//!    independently, and total time should grow near-linearly in shard
//!    count — while the unsharded planner pays the full O(n²) pair
//!    enumeration and a fleet-wide set-cover.  Plans must stay
//!    byte-identical between modes and across thread counts
//!    (`rust/tests/offline_determinism.rs` proves the identity; this
//!    bench spot-checks |M| and per-camera masks).
//!
//! Expected shape: sweep 1's filter stage dominates and grows
//! ~quadratically with cameras; with one worker per core the filter stage
//! — and at 8+ cameras the whole offline phase — should clear a ≥ 3×
//! speedup over `--offline-threads 1`.  Sweep 2's sharded time per
//! intersection should stay roughly flat from 4 to 16 intersections.

mod common;

use crossroi::bench::Table;
use crossroi::config::Config;
use crossroi::coordinator::Method;
use crossroi::offline::{
    build_plan_from_stream, build_plan_with, OfflineOptions, OfflinePlan, ShardMode, SolverKind,
};
use crossroi::sim::Scenario;
use crossroi::testing::fleet::disjoint_intersections;

fn stage(plan: &OfflinePlan, name: &str) -> f64 {
    plan.report.stage_seconds(name).unwrap_or(0.0)
}

fn single_intersection_sweep(base: &Config, threads: usize) {
    let mut table = Table::new(&[
        "cams",
        "constraints",
        "profile s",
        "filter s (1t)",
        "filter s (auto)",
        "solve s",
        "total s (1t)",
        "total s (auto)",
        "speedup",
    ]);
    for cams in [4usize, 8, 12, 16] {
        let mut cfg = base.clone();
        cfg.scenario.n_cameras = cams;
        let scenario = Scenario::build(&cfg.scenario);
        let sequential = build_plan_with(
            &scenario,
            &cfg.scenario,
            &cfg.system,
            &Method::CrossRoi,
            &OfflineOptions { threads: 1, solver: SolverKind::Greedy, shards: ShardMode::Off },
        )
        .unwrap();
        let parallel = build_plan_with(
            &scenario,
            &cfg.scenario,
            &cfg.system,
            &Method::CrossRoi,
            &OfflineOptions { threads: 0, solver: SolverKind::Greedy, shards: ShardMode::Off },
        )
        .unwrap();
        assert_eq!(
            sequential.masks.total_size(),
            parallel.masks.total_size(),
            "parallel plan diverged from sequential at {cams} cameras"
        );
        table.row(vec![
            format!("{cams}"),
            format!("{}", parallel.n_constraints),
            format!("{:.3}", stage(&parallel, "profile")),
            format!("{:.3}", stage(&sequential, "filter")),
            format!("{:.3}", stage(&parallel, "filter")),
            format!("{:.3}", stage(&parallel, "solve")),
            format!("{:.3}", sequential.seconds()),
            format!("{:.3}", parallel.seconds()),
            format!("{:.2}x", sequential.seconds() / parallel.seconds().max(1e-9)),
        ]);
    }
    table.print(&format!(
        "Offline planner scaling (single-intersection camera sweep, {threads} auto threads)"
    ));
}

fn disjoint_fleet_sweep(base: &Config) {
    let mut table = Table::new(&[
        "cams",
        "shards",
        "constraints",
        "|M|",
        "sharded s",
        "sharded s (1t)",
        "unsharded s",
        "speedup",
        "s/shard",
    ]);
    for n_intersections in [4usize, 8, 16] {
        let cams = 4 * n_intersections;
        let (stream, tiling) =
            disjoint_intersections(base, n_intersections, base.scenario.seed);
        let plan = |shards: ShardMode, threads: usize| -> OfflinePlan {
            build_plan_from_stream(
                &stream,
                &tiling,
                &base.system,
                &Method::CrossRoi,
                &OfflineOptions { threads, solver: SolverKind::Greedy, shards },
            )
            .unwrap()
        };
        let sharded = plan(ShardMode::Auto, 0);
        let sharded_1t = plan(ShardMode::Auto, 1);
        let unsharded = plan(ShardMode::Off, 0);
        // byte-identity spot checks (the full identity matrix lives in
        // rust/tests/offline_determinism.rs)
        assert_eq!(
            sharded.masks.total_size(),
            unsharded.masks.total_size(),
            "sharded |M| diverged from unsharded at {cams} cameras"
        );
        for cam in 0..cams {
            assert_eq!(
                sharded.masks.tiles[cam], sharded_1t.masks.tiles[cam],
                "sharded plan diverged across thread counts at cam {cam}"
            );
            assert_eq!(
                sharded.masks.tiles[cam], unsharded.masks.tiles[cam],
                "sharded mask diverged from unsharded at cam {cam}"
            );
        }
        let n_shards = sharded.report.shards.len().max(1);
        table.row(vec![
            format!("{cams}"),
            format!("{n_shards}"),
            format!("{}", sharded.n_constraints),
            format!("{}", sharded.masks.total_size()),
            format!("{:.3}", sharded.seconds()),
            format!("{:.3}", sharded_1t.seconds()),
            format!("{:.3}", unsharded.seconds()),
            format!("{:.2}x", unsharded.seconds() / sharded.seconds().max(1e-9)),
            format!("{:.4}", sharded.seconds() / n_shards as f64),
        ]);
    }
    table.print("Overlap-sharded planning (disjoint 4-camera intersections, 16-64 cameras)");
}

fn main() {
    let base = common::bench_config();
    let threads = OfflineOptions::default().effective_threads();
    println!(
        "offline scaling sweep: {}s profile window, {} worker threads (auto)",
        base.scenario.profile_secs, threads
    );
    single_intersection_sweep(&base, threads);
    disjoint_fleet_sweep(&base);
}
