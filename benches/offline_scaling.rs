//! Offline planner scaling — two sweeps for the staged planner:
//!
//! 1. **Single intersection, camera sweep** (4→16 cameras): per-stage
//!    seconds and the multi-thread speedup of the O(n²) pair fitting
//!    (ReXCam's argument: cross-camera correlation profiling is the
//!    city-scale bottleneck; this tracks how far the parallel planner
//!    pushes it).
//! 2. **Disjoint intersections, fleet sweep** (16→64 cameras as 4-camera
//!    intersections): overlap-sharded planning (`--shards auto`) against
//!    the single-instance planner on the same fleet.  The co-occurrence
//!    partition recovers the intersections, every shard plans
//!    independently, and total time should grow near-linearly in shard
//!    count — while the unsharded planner pays the full O(n²) pair
//!    enumeration and a fleet-wide set-cover.  Plans must stay
//!    byte-identical between modes and across thread counts
//!    (`rust/tests/offline_determinism.rs` proves the identity; this
//!    bench spot-checks |M| and per-camera masks).
//!
//! Expected shape: sweep 1's filter stage dominates and grows
//! ~quadratically with cameras; with one worker per core the filter stage
//! — and at 8+ cameras the whole offline phase — should clear a ≥ 3×
//! speedup over `--offline-threads 1`.  Sweep 2's sharded time per
//! intersection should stay roughly flat from 4 to 16 intersections.

mod common;

use std::time::Instant;

use crossroi::association::tiles::Tiling;
use crossroi::bench::Table;
use crossroi::config::Config;
use crossroi::coordinator::Method;
use crossroi::offline::{
    associate, build_plan_from_stream, build_plan_with, solve, OfflineOptions, OfflinePlan,
    ShardMode, SolverKind,
};
use crossroi::reid::error_model::{ErrorModelParams, RawReid};
use crossroi::sim::Scenario;
use crossroi::testing::fleet::disjoint_intersections;

fn stage(plan: &OfflinePlan, name: &str) -> f64 {
    plan.report.stage_seconds(name).unwrap_or(0.0)
}

fn single_intersection_sweep(base: &Config, threads: usize) {
    let mut table = Table::new(&[
        "cams",
        "constraints",
        "profile s",
        "filter s (1t)",
        "filter s (auto)",
        "solve s",
        "total s (1t)",
        "total s (auto)",
        "speedup",
    ]);
    for cams in [4usize, 8, 12, 16] {
        let mut cfg = base.clone();
        cfg.scenario.n_cameras = cams;
        let scenario = Scenario::build(&cfg.scenario);
        let sequential = build_plan_with(
            &scenario,
            &cfg.scenario,
            &cfg.system,
            &Method::CrossRoi,
            &OfflineOptions { threads: 1, solver: SolverKind::Greedy, shards: ShardMode::Off },
        )
        .unwrap();
        let parallel = build_plan_with(
            &scenario,
            &cfg.scenario,
            &cfg.system,
            &Method::CrossRoi,
            &OfflineOptions { threads: 0, solver: SolverKind::Greedy, shards: ShardMode::Off },
        )
        .unwrap();
        assert_eq!(
            sequential.masks.total_size(),
            parallel.masks.total_size(),
            "parallel plan diverged from sequential at {cams} cameras"
        );
        table.row(vec![
            format!("{cams}"),
            format!("{}", parallel.n_constraints),
            format!("{:.3}", stage(&parallel, "profile")),
            format!("{:.3}", stage(&sequential, "filter")),
            format!("{:.3}", stage(&parallel, "filter")),
            format!("{:.3}", stage(&parallel, "solve")),
            format!("{:.3}", sequential.seconds()),
            format!("{:.3}", parallel.seconds()),
            format!("{:.2}x", sequential.seconds() / parallel.seconds().max(1e-9)),
        ]);
    }
    table.print(&format!(
        "Offline planner scaling (single-intersection camera sweep, {threads} auto threads)"
    ));
}

fn disjoint_fleet_sweep(base: &Config) {
    let mut table = Table::new(&[
        "cams",
        "shards",
        "constraints",
        "|M|",
        "sharded s",
        "sharded s (1t)",
        "unsharded s",
        "speedup",
        "s/shard",
    ]);
    for n_intersections in [4usize, 8, 16] {
        let cams = 4 * n_intersections;
        let (stream, tiling) =
            disjoint_intersections(base, n_intersections, base.scenario.seed);
        let plan = |shards: ShardMode, threads: usize| -> OfflinePlan {
            build_plan_from_stream(
                &stream,
                &tiling,
                &base.system,
                &Method::CrossRoi,
                &OfflineOptions { threads, solver: SolverKind::Greedy, shards },
            )
            .unwrap()
        };
        let sharded = plan(ShardMode::Auto, 0);
        let sharded_1t = plan(ShardMode::Auto, 1);
        let unsharded = plan(ShardMode::Off, 0);
        // byte-identity spot checks (the full identity matrix lives in
        // rust/tests/offline_determinism.rs)
        assert_eq!(
            sharded.masks.total_size(),
            unsharded.masks.total_size(),
            "sharded |M| diverged from unsharded at {cams} cameras"
        );
        for cam in 0..cams {
            assert_eq!(
                sharded.masks.tiles[cam], sharded_1t.masks.tiles[cam],
                "sharded plan diverged across thread counts at cam {cam}"
            );
            assert_eq!(
                sharded.masks.tiles[cam], unsharded.masks.tiles[cam],
                "sharded mask diverged from unsharded at cam {cam}"
            );
        }
        let n_shards = sharded.report.shards.len().max(1);
        table.row(vec![
            format!("{cams}"),
            format!("{n_shards}"),
            format!("{}", sharded.n_constraints),
            format!("{}", sharded.masks.total_size()),
            format!("{:.3}", sharded.seconds()),
            format!("{:.3}", sharded_1t.seconds()),
            format!("{:.3}", unsharded.seconds()),
            format!("{:.2}x", unsharded.seconds() / sharded.seconds().max(1e-9)),
            format!("{:.4}", sharded.seconds() / n_shards as f64),
        ]);
    }
    table.print("Overlap-sharded planning (disjoint 4-camera intersections, 16-64 cameras)");
}

/// Continuous re-profiling (DESIGN.md §7): warm-started re-solve
/// (`Solver::resolve` via `solve::run_incremental`) against a
/// from-scratch solve on a window slid by various fractions.  The slid
/// window keeps most of its constraints, so the warm seed closes them for
/// free and only the novel tail pays greedy rounds — re-solve time should
/// sit well under from-scratch across the sweep.
fn warm_start_sweep(base: &Config) {
    let mut cfg = base.clone();
    cfg.scenario.n_cameras = 8;
    // drifting traffic so the slid windows genuinely change
    cfg.scenario.drift_at_secs = cfg.scenario.profile_secs;
    cfg.scenario.drift_strength = 0.75;
    let scenario = Scenario::build(&cfg.scenario);
    let tiling = Tiling::new(
        cfg.scenario.n_cameras,
        crossroi::sim::FRAME_W,
        crossroi::sim::FRAME_H,
        cfg.scenario.tile_px,
    );
    let window = scenario.profile_range().len();
    let params = ErrorModelParams::default();
    let base_stream = RawReid::generate(&scenario, 0..window, &params);
    let base_table = associate::run(&base_stream, &tiling).table;
    let solver = SolverKind::Greedy.build();
    let prev = solve::run(&base_table, solver.as_ref());

    let reps = 5;
    let mut table = Table::new(&[
        "slide",
        "constraints",
        "novel",
        "fresh ms",
        "warm ms",
        "speedup",
        "|M| fresh",
        "|M| warm",
    ]);
    for slide_frac in [0.1f64, 0.25, 0.5] {
        let slide = ((window as f64 * slide_frac) as usize).max(1);
        let end = (slide + window).min(scenario.n_frames());
        let stream = RawReid::generate(&scenario, slide..end, &params);
        let slid = associate::run(&stream, &tiling).table;
        let base_set: std::collections::HashSet<&crossroi::association::table::Constraint> =
            base_table.constraints.iter().collect();
        let novel = slid.constraints.iter().filter(|c| !base_set.contains(*c)).count();
        let time = |f: &dyn Fn() -> usize| -> (f64, usize) {
            let mut best = f64::INFINITY;
            let mut size = 0;
            for _ in 0..reps {
                let t0 = Instant::now();
                size = f();
                best = best.min(t0.elapsed().as_secs_f64());
            }
            (best, size)
        };
        let (fresh_s, fresh_m) = time(&|| solve::run(&slid, solver.as_ref()).solution.size());
        let (warm_s, warm_m) =
            time(&|| solve::run_incremental(&slid, solver.as_ref(), &prev.solution).solution.size());
        // noise-tolerant backstop: the table shows the real speedup; this
        // only trips when warm-starting regresses to slower than scratch
        assert!(
            warm_s <= fresh_s * 1.25,
            "warm re-solve ({warm_s:.4}s) regressed past from-scratch ({fresh_s:.4}s) at slide {slide_frac}"
        );
        table.row(vec![
            format!("{:.0}%", slide_frac * 100.0),
            format!("{}", slid.n_constraints()),
            format!("{novel}"),
            format!("{:.2}", fresh_s * 1e3),
            format!("{:.2}", warm_s * 1e3),
            format!("{:.2}x", fresh_s / warm_s.max(1e-9)),
            format!("{fresh_m}"),
            format!("{warm_m}"),
        ]);
    }
    table.print("Warm-start re-solve vs from-scratch (slid profile window, 8 drifting cameras)");
}

fn main() {
    let base = common::bench_config();
    let threads = OfflineOptions::default().effective_threads();
    println!(
        "offline scaling sweep: {}s profile window, {} worker threads (auto)",
        base.scenario.profile_secs, threads
    );
    single_intersection_sweep(&base, threads);
    disjoint_fleet_sweep(&base);
    warm_start_sweep(&base);
}
